"""Jacobi generator: the first kernel with NO hand-written specs at all.

Every candidate is traced from its Pallas builder; TPU operand specs, grid
dependences, *and* the cost model's VPU counts and work units are derived
from the traced body (DESIGN §9).  The GPU address expressions come from
the same trace — ``traced_gpu_spec`` lowers the rowstream body's five taps
into the classic per-point 5-point-stencil spec, so one traced kernel
prices on V100/A100/TPUv5e in a single ``Explorer.explore`` sweep.
"""
from __future__ import annotations

from functools import lru_cache

from repro.kernels import dtype_for
from repro.core.machines import TPUMachine, TPU_V5E
from repro.core.tpu_adapt import select_pallas_config


FLOPS_PER_POINT = 5.0  # 4 adds + 1 mul equivalent (matches the paper's 2d5pt)


def _space(domain: tuple):
    Y, _X = domain
    yield {"variant": "rowstream"}
    ty = 8
    while ty <= Y // 2:
        if Y % ty == 0:
            yield {"variant": "ytile", "ty": ty}
        ty *= 2


@lru_cache(maxsize=None)
def _candidates(domain: tuple, elem_bytes: int) -> tuple:
    import jax.numpy as jnp

    from repro.frontend import CostModel, KernelBuild, arg, candidates

    from .kernel import make_kernel

    Y, X = domain
    dtype = dtype_for(elem_bytes)
    # vpu_elems / vpu_shape / work_per_step all derive from the traced body
    costs = CostModel(elem_bytes=elem_bytes, flops_per_point=FLOPS_PER_POINT)

    def build(cfg):
        variant, ty = cfg["variant"], cfg.get("ty")
        call = make_kernel(variant, domain, dtype=dtype, ty=ty)
        if variant == "rowstream":
            shape = (Y + 2, X + 2)
            name = "jacobi2d_rowstream"
        else:
            shape = ((Y // ty + 1) * ty, X + 2)
            name = f"jacobi2d_ytile{ty}"
        return KernelBuild(call, (arg("src", shape, dtype),), name=name,
                           out_names=("dst",), costs=costs, trace_body=True)

    return tuple(candidates(build, _space(domain)))


def candidate_specs(domain: tuple, elem_bytes: int = 4):
    yield from _candidates(tuple(domain), elem_bytes)


@lru_cache(maxsize=None)
def traced_gpu_spec(domain: tuple, elem_bytes: int = 8,
                    name: str = "jacobi2d"):
    """Per-point GPU address expressions traced from the rowstream body."""
    import jax.numpy as jnp

    from repro.frontend import CostModel, arg, lower_gpu, trace_kernel

    from .kernel import make_rowstream

    Y, X = domain
    dtype = dtype_for(elem_bytes)
    traced = trace_kernel(
        make_rowstream(tuple(domain), (0.5, 0.125), dtype),
        (arg("src", (Y + 2, X + 2), dtype),),
        name=name, out_names=("dst",), trace_body=True)
    return lower_gpu(traced, CostModel(flops_per_point=FLOPS_PER_POINT),
                     name=name)


def rank_configs(domain: tuple, machine: TPUMachine = TPU_V5E,
                 elem_bytes: int = 4):
    return select_pallas_config(candidate_specs(domain, elem_bytes), machine)


def generate(domain: tuple, weights=(0.5, 0.125),
             machine: TPUMachine = TPU_V5E, elem_bytes: int = 4, dtype=None):
    import jax.numpy as jnp

    from .kernel import make_kernel

    ranked = rank_configs(domain, machine, elem_bytes)
    if not ranked:
        raise RuntimeError("no feasible jacobi2d configuration")
    best = ranked[0]
    kern = make_kernel(best.config["variant"], domain, weights,
                       dtype or jnp.float32, best.config.get("ty"))
    return kern, best
