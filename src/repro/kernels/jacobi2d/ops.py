"""Jit'd public API for the traced Jacobi kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .generator import rank_configs
from .kernel import make_kernel


@functools.partial(jax.jit, static_argnames=("weights", "variant", "ty"))
def _apply(src, *, weights: tuple, variant: str, ty):
    Y, X = src.shape
    padded = jnp.pad(src, 1)
    if variant == "ytile":
        t = ty or 8
        extra = (Y // t + 1) * t - (Y + 2)
        padded = jnp.pad(padded, ((0, extra), (0, 0)))
    return make_kernel(variant, (Y, X), weights, src.dtype, ty)(padded)


def jacobi_step(src, weights=(0.5, 0.125), config: dict | None = None):
    """One weighted Jacobi sweep; configuration chosen by the estimator
    (from purely traced specs) unless pinned via ``config``."""
    if config is None:
        ranked = rank_configs(src.shape, elem_bytes=src.dtype.itemsize)
        if not ranked:
            raise RuntimeError("no feasible jacobi2d configuration")
        config = ranked[0].config
    w = tuple(float(x) for x in weights)
    return _apply(src, weights=w, variant=config["variant"],
                  ty=config.get("ty"))


def jacobi_ref(src, weights=(0.5, 0.125)):
    """Pure-jnp oracle on the unpadded source (zero boundary)."""
    wc, wn = weights
    p = jnp.pad(src, 1)
    return (wc * p[1:-1, 1:-1]
            + wn * (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]))
