"""Pallas TPU kernels for the weighted 2D 5-point Jacobi stencil.

dst[y, x] = wc * src[y, x] + wn * (src[y-1, x] + src[y+1, x]
                                   + src[y, x-1] + src[y, x+1])

on a halo-padded source.  Two variants whose configuration the estimator
selects analytically — and whose specs exist *only* through the tracing
frontend (DESIGN §9); nothing here is hand-lowered:

  * ``rowstream`` — grid over rows; three row refs (y, y+1, y+2 of the
    padded plane) supply the y-halo, x-halo via static slices.  Per-point
    affine accesses, so the frontend lowers it for the GPU backend too.
  * ``ytile``    — grid over y-tiles; two tile refs (j, j+1) supply the
    tile+halo rows via concatenation (the established tile+halo trick).
    Fewer grid steps, bigger blocks; y-halo rows are refetched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INTERPRET = True


def make_rowstream(domain: tuple, weights, dtype=jnp.float32):
    Y, X = domain
    Xp = X + 2
    wc, wn = (float(w) for w in weights)

    def kernel(r0, r1, r2, o_ref):
        def sl(row, x0):
            return jax.lax.dynamic_slice(row[0], (x0,), (X,))

        # access order mirrors the canonical 2d5pt spec: center, up, down,
        # left, right
        c = sl(r1, 1)
        u = sl(r0, 1)
        d = sl(r2, 1)
        le = sl(r1, 0)
        ri = sl(r1, 2)
        o_ref[0] = wc * c + wn * (u + d + le + ri)

    def call(src_padded):
        """src_padded: (Y + 2, X + 2)."""
        in_specs = [
            pl.BlockSpec((1, Xp), lambda y, k=k: (y + k, 0)) for k in range(3)
        ]
        return pl.pallas_call(
            kernel,
            grid=(Y,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, X), lambda y: (y, 0)),
            out_shape=jax.ShapeDtypeStruct((Y, X), dtype),
            interpret=_INTERPRET,
        )(*([src_padded] * 3))

    return call


def make_ytile(domain: tuple, ty: int, weights, dtype=jnp.float32):
    Y, X = domain
    if Y % ty or ty < 2:
        raise ValueError("ty must divide Y and be >= 2")
    ny = Y // ty
    Xp = X + 2
    wc, wn = (float(w) for w in weights)

    def kernel(a_ref, b_ref, o_ref):
        rows = jnp.concatenate([a_ref[...], b_ref[...]], axis=0)

        def sl(y0, x0):
            return jax.lax.dynamic_slice(rows, (y0, x0), (ty, X))

        o_ref[...] = wc * sl(1, 1) + wn * (sl(0, 1) + sl(2, 1)
                                           + sl(1, 0) + sl(1, 2))

    def call(src_padded_y):
        """src_padded_y: ((ny + 1) * ty, X + 2) — 1 halo row at the top,
        padded to a whole extra tile at the bottom (ops.py prepares it)."""
        return pl.pallas_call(
            kernel,
            grid=(ny,),
            in_specs=[
                pl.BlockSpec((ty, Xp), lambda j: (j, 0)),
                pl.BlockSpec((ty, Xp), lambda j: (j + 1, 0)),
            ],
            out_specs=pl.BlockSpec((ty, X), lambda j: (j, 0)),
            out_shape=jax.ShapeDtypeStruct((Y, X), dtype),
            interpret=_INTERPRET,
        )(src_padded_y, src_padded_y)

    return call


VARIANTS = ("rowstream", "ytile")


def make_kernel(variant: str, domain: tuple, weights=(0.5, 0.125),
                dtype=jnp.float32, ty=None):
    if variant == "rowstream":
        return make_rowstream(domain, weights, dtype)
    if variant == "ytile":
        return make_ytile(domain, ty or 8, weights, dtype)
    raise ValueError(f"unknown variant {variant}")
