"""2D 5-point Jacobi kernel package — priced *only* via the spec-extraction
frontend (no hand-written specs anywhere).  Submodules load lazily so the
traced decision space can be enumerated without importing jax up front."""
from repro.kernels import lazy_submodules

__getattr__, __dir__ = lazy_submodules(__name__, ("generator", "kernel", "ops"))
