"""Jit'd public API for the LBM interface-tracking kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .generator import rank_configs
from .kernel import make_kernel


@functools.partial(jax.jit, static_argnames=("variant", "ty", "tau", "kappa"))
def _apply(pdf, phase, *, variant: str, ty, tau: float, kappa: float):
    q, Z, Y, X = pdf.shape
    pdf_p = jnp.pad(pdf, ((0, 0), (1, 1), (1, 1), (1, 1)))
    ph_p = jnp.pad(phase, ((1, 1), (1, 1), (1, 1)))
    ty_val = None
    if variant == "ytile":
        ty_val = ty or 8
        ny = Y // ty_val
        extra = (ny + 1) * ty_val - (Y + 2)
        pdf_p = jnp.pad(pdf_p, ((0, 0), (0, 0), (0, extra), (0, 0)))
        ph_p = jnp.pad(ph_p, ((0, 0), (0, extra), (0, 0)))
    kern = make_kernel(variant, (Z, Y, X), ty_val, tau, kappa, pdf.dtype)
    return kern(pdf_p, ph_p)


def lbm_step(pdf, phase, tau: float = 0.8, kappa: float = 0.15, config: dict | None = None):
    """One pull-scheme interface-tracking step; config picked analytically."""
    if config is None:
        ranked = rank_configs(pdf.shape[1:], elem_bytes=pdf.dtype.itemsize)
        if not ranked:
            raise RuntimeError("no feasible config")
        config = ranked[0].config
    new_pdf = _apply(
        pdf, phase, variant=config["variant"], ty=config.get("ty"), tau=tau, kappa=kappa
    )
    return new_pdf, new_pdf.sum(axis=0)
