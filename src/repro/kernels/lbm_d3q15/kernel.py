"""Pallas TPU kernels for the D3Q15 Allen-Cahn interface-tracking LBM.

The z-streaming of the pull scheme is expressed *entirely in the BlockSpec
index maps*: PDF q's input ref maps grid step t to padded plane t+1-cz(q),
so every PDF plane is fetched exactly once (revisit analysis gives fetch
multiplicity 1 per plane) — the TPU equivalent of the GPU's streaming-store
friendliness the paper measures.  x/y shifts stay in-plane via static slices
of the halo-padded planes.

Variants:
  * ``replane`` — 15 PDF plane refs + 3 phase plane refs; no scratch.
  * ``ytile``   — all fields y-tiled (2 refs each for the tile+halo trick)
    for domains whose planes violate the VMEM capacity (layer) condition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import VELOCITIES, WEIGHTS

_INTERPRET = True


def _compute(planes_q, phase_m, phase_c, phase_p, Y, X, y0, tau, kappa):
    """Shared collide+stream math on padded (rows, Xp) planes.

    planes_q[q]: padded plane of PDF q already at the right z (pull).
    phase_m/c/p: phase planes at z-1, z, z+1.
    y0: row offset of the output origin inside the padded planes.
    Returns (15, Y, X) new PDFs.
    """

    def sl(a, dy, dx):
        return jax.lax.dynamic_slice(a, (y0 + dy, 1 + dx), (Y, X))

    phi = sl(phase_c, 0, 0)
    gx = 0.5 * (sl(phase_c, 0, 1) - sl(phase_c, 0, -1))
    gy = 0.5 * (sl(phase_c, 1, 0) - sl(phase_c, -1, 0))
    gz = 0.5 * (sl(phase_p, 0, 0) - sl(phase_m, 0, 0))
    inv = jax.lax.rsqrt(gx * gx + gy * gy + gz * gz + 1e-12)
    sharp = kappa * phi * (1.0 - phi)
    out = []
    for qi, (cx, cy, cz) in enumerate(VELOCITIES):
        w = WEIGHTS[qi]
        h = sl(planes_q[qi], -cy, -cx)
        cdotn = (cx * gx + cy * gy + cz * gz) * inv
        heq = w * phi + w * sharp * cdotn
        out.append(h - (h - heq) / tau)
    return jnp.stack(out)


def make_replane(domain: tuple, tau: float = 0.8, kappa: float = 0.15, dtype=jnp.float32):
    Z, Y, X = domain
    Yp, Xp = Y + 2, X + 2

    def kernel(*refs):
        pdf_refs = refs[:15]
        ph_m, ph_c, ph_p = refs[15:18]
        o_ref = refs[18]
        planes = [pdf_refs[q][0, 0] for q in range(15)]
        o_ref[:, 0] = _compute(
            planes, ph_m[0], ph_c[0], ph_p[0], Y, X, 1, tau, kappa
        )

    def call(pdf_padded, phase_padded):
        """pdf_padded (15, Z+2, Yp, Xp), phase_padded (Z+2, Yp, Xp)."""
        in_specs = []
        for q, (cx, cy, cz) in enumerate(VELOCITIES):
            in_specs.append(
                pl.BlockSpec(
                    (1, 1, Yp, Xp),
                    functools.partial(lambda q, cz, t: (q, t + 1 - cz, 0, 0), q, cz),
                )
            )
        for k in range(3):
            in_specs.append(
                pl.BlockSpec((1, Yp, Xp), functools.partial(lambda k, t: (t + k, 0, 0), k))
            )
        return pl.pallas_call(
            kernel,
            grid=(Z,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((15, 1, Y, X), lambda t: (0, t, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((15, Z, Y, X), dtype),
            interpret=_INTERPRET,
        )(*([pdf_padded] * 15 + [phase_padded] * 3))

    return call


def make_ytile(domain: tuple, ty: int, tau: float = 0.8, kappa: float = 0.15, dtype=jnp.float32):
    """y-tiled variant: per field two y-blocks (tile j and j+1) supply the
    tile+halo rows; requires ty >= 2 and ty | Y.  ops.py pads y to
    (ny+1)*ty rows so block j+1 stays in bounds."""
    Z, Y, X = domain
    if Y % ty or ty < 2:
        raise ValueError("ty must divide Y and be >= 2")
    ny = Y // ty
    Xp = X + 2

    def kernel(*refs):
        pdf_a = refs[:15]
        pdf_b = refs[15:30]
        ph = refs[30:36]  # (m_a, m_b, c_a, c_b, p_a, p_b)
        o_ref = refs[36]
        planes = [
            jnp.concatenate([pdf_a[q][0, 0], pdf_b[q][0, 0]], axis=0) for q in range(15)
        ]
        ph_m = jnp.concatenate([ph[0][0], ph[1][0]], axis=0)
        ph_c = jnp.concatenate([ph[2][0], ph[3][0]], axis=0)
        ph_p = jnp.concatenate([ph[4][0], ph[5][0]], axis=0)
        o_ref[:, 0] = _compute(planes, ph_m, ph_c, ph_p, ty, X, 1, tau, kappa)

    def call(pdf_padded, phase_padded):
        """pdf_padded (15, Z+2, (ny+1)*ty, Xp), phase same y alloc."""
        in_specs = []
        for dj in (0, 1):
            for q, (cx, cy, cz) in enumerate(VELOCITIES):
                in_specs.append(
                    pl.BlockSpec(
                        (1, 1, ty, Xp),
                        functools.partial(
                            lambda q, cz, dj, j, t: (q, t + 1 - cz, j + dj, 0), q, cz, dj
                        ),
                    )
                )
        for k in range(3):
            for dj in (0, 1):
                in_specs.append(
                    pl.BlockSpec(
                        (1, ty, Xp),
                        functools.partial(lambda k, dj, j, t: (t + k, j + dj, 0), k, dj),
                    )
                )
        args = [pdf_padded] * 30 + [phase_padded] * 6
        return pl.pallas_call(
            kernel,
            grid=(ny, Z),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((15, 1, ty, X), lambda j, t: (0, t, j, 0)),
            out_shape=jax.ShapeDtypeStruct((15, Z, Y, X), dtype),
            interpret=_INTERPRET,
        )(*args)

    return call


def make_kernel(variant: str, domain: tuple, ty=None, tau=0.8, kappa=0.15, dtype=jnp.float32):
    if variant == "replane":
        return make_replane(domain, tau, kappa, dtype)
    if variant == "ytile":
        return make_ytile(domain, ty or 8, tau, kappa, dtype)
    raise ValueError(variant)
