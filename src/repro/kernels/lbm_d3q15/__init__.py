"""Generator + kernel package; submodules load lazily so the generator's
analytical decision space can be priced without importing jax."""
from repro.kernels import lazy_submodules

__getattr__, __dir__ = lazy_submodules(__name__, ("generator", "kernel", "ops", "ref"))
