"""LBM kernel generator + estimator coupling (paper §5.3 on TPU)."""
from __future__ import annotations

from repro.core.machines import TPUMachine, TPU_V5E
from repro.core.tpu_adapt import OperandSpec, PallasKernelSpec, select_pallas_config

FLOPS_PER_LUP = 15 * 8 + 25  # relax+equilibrium per PDF + gradient/normal math


def candidate_specs(domain: tuple, elem_bytes: int = 4):
    Z, Y, X = domain
    Yp, Xp = Y + 2, X + 2

    # replane
    ops = tuple(
        OperandSpec(f"pdf{q}", (1, 1, Yp, Xp), elem_bytes, grid_deps=(0,))
        for q in range(15)
    ) + tuple(
        OperandSpec(f"phase{k}", (1, Yp, Xp), elem_bytes, grid_deps=(0,)) for k in range(3)
    ) + (
        OperandSpec("dst", (15, 1, Y, X), elem_bytes, grid_deps=(0,), is_output=True),
    )
    yield (
        {"variant": "replane"},
        PallasKernelSpec(
            name="lbm_replane",
            grid=(Z,),
            operands=ops,
            vpu_elems_per_step=float(FLOPS_PER_LUP * Y * X),
            vpu_shape=(Y, X),
            work_per_step=float(Y * X),
            elem_bytes=elem_bytes,
        ),
    )

    ty = 8
    while ty <= Y // 2:
        if Y % ty == 0:
            ops_t = tuple(
                OperandSpec(f"pdf{q}_{dj}", (1, 1, ty, Xp), elem_bytes, grid_deps=(0, 1))
                for dj in (0, 1)
                for q in range(15)
            ) + tuple(
                OperandSpec(f"phase{k}_{dj}", (1, ty, Xp), elem_bytes, grid_deps=(0, 1))
                for k in range(3)
                for dj in (0, 1)
            ) + (
                OperandSpec(
                    "dst", (15, 1, ty, X), elem_bytes, grid_deps=(0, 1), is_output=True
                ),
            )
            yield (
                {"variant": "ytile", "ty": ty},
                PallasKernelSpec(
                    name=f"lbm_ytile{ty}",
                    grid=(Y // ty, Z),
                    operands=ops_t,
                    vpu_elems_per_step=float(FLOPS_PER_LUP * ty * X),
                    vpu_shape=(ty, X),
                    work_per_step=float(ty * X),
                    elem_bytes=elem_bytes,
                ),
            )
        ty *= 2


def rank_configs(domain: tuple, machine: TPUMachine = TPU_V5E, elem_bytes: int = 4):
    return select_pallas_config(candidate_specs(domain, elem_bytes), machine)


def generate(domain: tuple, machine: TPUMachine = TPU_V5E, elem_bytes: int = 4, **kw):
    from .kernel import make_kernel

    ranked = rank_configs(domain, machine, elem_bytes)
    if not ranked:
        raise RuntimeError("no feasible LBM configuration")
    best = ranked[0]
    kern = make_kernel(best.config["variant"], domain, best.config.get("ty"), **kw)
    return kern, best
