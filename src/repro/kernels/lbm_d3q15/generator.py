"""LBM kernel generator + estimator coupling (paper §5.3 on TPU).

The D3Q15 replane candidate carries 19 operands and the y-tiled one 37 —
exactly the hand-maintained spec boilerplate the spec-extraction frontend
(DESIGN §9) exists to delete.  Every candidate is now traced from the
actual Pallas kernel: the z-streaming index maps (``t + 1 - cz`` per PDF),
the tile+halo double refs, and the output block all become address
expressions mechanically.  Only the collide+stream flop estimate remains a
hand-pinned physics constant.
"""
from __future__ import annotations

from functools import lru_cache

from repro.kernels import dtype_for
from repro.core.machines import TPUMachine, TPU_V5E
from repro.core.tpu_adapt import select_pallas_config

FLOPS_PER_LUP = 15 * 8 + 25  # relax+equilibrium per PDF + gradient/normal math


def _space(domain: tuple):
    _Z, Y, _X = domain
    yield {"variant": "replane"}
    ty = 8
    while ty <= Y // 2:
        if Y % ty == 0:
            yield {"variant": "ytile", "ty": ty}
        ty *= 2


@lru_cache(maxsize=None)
def _candidates(domain: tuple, elem_bytes: int) -> tuple:
    import jax.numpy as jnp

    from repro.frontend import CostModel, KernelBuild, arg, candidates

    from .kernel import make_kernel

    Z, Y, X = domain
    Yp, Xp = Y + 2, X + 2
    dtype = dtype_for(elem_bytes)

    def build(cfg):
        variant, ty = cfg["variant"], cfg.get("ty")
        call = make_kernel(variant, domain, ty, dtype=dtype)
        if variant == "replane":
            return KernelBuild(
                call,
                (arg("pdf", (15, Z + 2, Yp, Xp), dtype),
                 arg("phase", (Z + 2, Yp, Xp), dtype)),
                name="lbm_replane",
                operand_names=[f"pdf{q}" for q in range(15)]
                + [f"phase{k}" for k in range(3)] + ["dst"],
                costs=CostModel(
                    vpu_elems_per_step=float(FLOPS_PER_LUP * Y * X),
                    vpu_shape=(Y, X), work_per_step=float(Y * X),
                    elem_bytes=elem_bytes))
        y_alloc = (Y // ty + 1) * ty
        return KernelBuild(
            call,
            (arg("pdf", (15, Z + 2, y_alloc, Xp), dtype),
             arg("phase", (Z + 2, y_alloc, Xp), dtype)),
            name=f"lbm_ytile{ty}",
            operand_names=[f"pdf{q}_{dj}" for dj in (0, 1) for q in range(15)]
            + [f"phase{k}_{dj}" for k in range(3) for dj in (0, 1)] + ["dst"],
            costs=CostModel(
                vpu_elems_per_step=float(FLOPS_PER_LUP * ty * X),
                vpu_shape=(ty, X), work_per_step=float(ty * X),
                elem_bytes=elem_bytes))

    return tuple(candidates(build, _space(domain)))


def candidate_specs(domain: tuple, elem_bytes: int = 4):
    yield from _candidates(tuple(domain), elem_bytes)


def rank_configs(domain: tuple, machine: TPUMachine = TPU_V5E, elem_bytes: int = 4):
    return select_pallas_config(candidate_specs(domain, elem_bytes), machine)


def generate(domain: tuple, machine: TPUMachine = TPU_V5E, elem_bytes: int = 4, **kw):
    from .kernel import make_kernel

    ranked = rank_configs(domain, machine, elem_bytes)
    if not ranked:
        raise RuntimeError("no feasible LBM configuration")
    best = ranked[0]
    kern = make_kernel(best.config["variant"], domain, best.config.get("ty"), **kw)
    return kern, best
