"""Pure-jnp oracle for the D3Q15 Allen-Cahn interface-tracking LBM kernel.

Physics (conservative Allen-Cahn phase-field LBM, paper §5.3): the kernel
pulls 15 PDFs from upstream neighbors, computes the phase-field gradient with
a 3D 7-point central-difference stencil (curvature/sharpening term), relaxes
toward an equilibrium with an interface-sharpening flux along the interface
normal, and stores 15 PDFs aligned.  240 B/LUP streaming + the stencil
component — exactly the access mix the paper analyzes.
"""
from __future__ import annotations

import jax.numpy as jnp

# D3Q15 velocities and weights
VELOCITIES = (
    (0, 0, 0),
    (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1),
    (1, 1, 1), (-1, -1, -1), (1, 1, -1), (-1, -1, 1),
    (1, -1, 1), (-1, 1, -1), (-1, 1, 1), (1, -1, -1),
)
WEIGHTS = (2 / 9,) + (1 / 9,) * 6 + (1 / 72,) * 8


def lbm_step_ref(pdf_padded, phase_padded, tau: float = 0.8, kappa: float = 0.15):
    """One interface-tracking step.

    pdf_padded:   (15, Z+2, Y+2, X+2) — halo-1 padded PDFs
    phase_padded: (Z+2, Y+2, X+2)     — halo-1 padded phase field
    Returns (pdf_new (15,Z,Y,X), phase_new (Z,Y,X)).
    """
    q, zp, yp, xp = pdf_padded.shape
    Z, Y, X = zp - 2, yp - 2, xp - 2

    def ip(a, dz, dy, dx):  # interior slice with offset
        return a[1 + dz : 1 + dz + Z, 1 + dy : 1 + dy + Y, 1 + dx : 1 + dx + X]

    phi = ip(phase_padded, 0, 0, 0)
    gx = 0.5 * (ip(phase_padded, 0, 0, 1) - ip(phase_padded, 0, 0, -1))
    gy = 0.5 * (ip(phase_padded, 0, 1, 0) - ip(phase_padded, 0, -1, 0))
    gz = 0.5 * (ip(phase_padded, 1, 0, 0) - ip(phase_padded, -1, 0, 0))
    inv = (gx * gx + gy * gy + gz * gz + 1e-12) ** -0.5
    sharp = kappa * phi * (1.0 - phi)

    new = []
    phase_new = 0.0
    for qi, (cx, cy, cz) in enumerate(VELOCITIES):
        w = WEIGHTS[qi]
        # pull: PDF qi streamed from cell - c
        h = ip(pdf_padded[qi], -cz, -cy, -cx)
        cdotn = (cx * gx + cy * gy + cz * gz) * inv
        heq = w * phi + w * sharp * cdotn
        hnew = h - (h - heq) / tau
        new.append(hnew)
        phase_new = phase_new + hnew
    return jnp.stack(new), phase_new


def pad_inputs(pdf, phase):
    return (
        jnp.pad(pdf, ((0, 0), (1, 1), (1, 1), (1, 1))),
        jnp.pad(phase, ((1, 1), (1, 1), (1, 1))),
    )
