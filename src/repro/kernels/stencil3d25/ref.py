"""Pure-jnp oracle for the range-r 3D star stencil (paper §5.2)."""
from __future__ import annotations

import jax.numpy as jnp


def star_weights(r: int, dtype=jnp.float32):
    """Default weights: uniform average over the 6r+1 points."""
    n = 6 * r + 1
    return jnp.full((n,), 1.0 / n, dtype=dtype)


def star_stencil_ref(src_padded, weights, r: int):
    """dst[z,y,x] = w0*src[z,y,x] + sum_axis sum_o w[...] * src[..+-o..].

    ``src_padded`` has halo r on every side; weights ordered
    [center, (z,-1),(z,+1),...,(z,-r),(z,+r), (y,..), (x,..)].
    """
    zp, yp, xp = src_padded.shape
    Z, Y, X = zp - 2 * r, yp - 2 * r, xp - 2 * r

    def sl(dz, dy, dx):
        return src_padded[
            r + dz : r + dz + Z, r + dy : r + dy + Y, r + dx : r + dx + X
        ]

    out = weights[0] * sl(0, 0, 0)
    w = 1
    for axis in range(3):
        for o in range(1, r + 1):
            for s in (-o, o):
                d = [0, 0, 0]
                d[axis] = s
                out = out + weights[w] * sl(*d)
                w += 1
    return out


def pad_input(src, r: int):
    return jnp.pad(src, ((r, r), (r, r), (r, r)))
