"""Stencil code generator + estimator coupling (paper fig. 1, on TPU).

``candidate_specs`` enumerates the generator's decision space (variant x
tile size) and — via the spec-extraction frontend (DESIGN §9) — *traces*
each candidate's actual Pallas kernel into the address-expression artifact
the estimator prices, before any code runs.  The generator no longer
hand-writes a single ``OperandSpec``: grids, block shapes, grid
dependences, and VMEM scratch residency all come out of the kernel builder
itself, so the spec cannot drift from the code.  Only the flop model stays
hand-pinned physics.  ``generate`` then materializes the winning kernel.
"""
from __future__ import annotations

from functools import lru_cache

from repro.kernels import dtype_for
from repro.core.machines import TPUMachine, TPU_V5E
from repro.core.tpu_adapt import RankedPallasConfig, select_pallas_config


def _flops_per_point(r: int) -> float:
    return float(6 * r + 1) * 2.0  # mul + add per tap


def _space(r: int, domain: tuple):
    _Z, Y, _X = domain
    yield {"variant": "replane"}
    yield {"variant": "ring"}
    ty = max(2 * r, 8)
    while ty <= Y // 2:
        if Y % ty == 0:
            yield {"variant": "ytile_ring", "ty": ty}
        ty *= 2


@lru_cache(maxsize=None)
def _candidates(r: int, domain: tuple, elem_bytes: int) -> tuple:
    import jax.numpy as jnp

    from repro.frontend import CostModel, KernelBuild, arg, candidates

    from .kernel import make_kernel

    Z, Y, X = domain
    Yp, Xp = Y + 2 * r, X + 2 * r
    Zp = Z + 2 * r
    dtype = dtype_for(elem_bytes)
    fl = _flops_per_point(r)
    weights = (1.0,) * (6 * r + 1)  # codegen constants; irrelevant to specs

    def build(cfg):
        variant, ty = cfg["variant"], cfg.get("ty")
        call = make_kernel(variant, r, domain, weights, dtype, ty)
        if variant == "replane":
            return KernelBuild(
                call, (arg("src", (Zp, Yp, Xp), dtype),),
                name=f"star{r}_replane",
                operand_names=[f"src_p{k}" for k in range(2 * r + 1)]
                + ["dst"],
                costs=CostModel(vpu_elems_per_step=fl * Y * X,
                                vpu_shape=(Y, X), work_per_step=float(Y * X),
                                elem_bytes=elem_bytes))
        if variant == "ring":
            return KernelBuild(
                call, (arg("src", (Zp, Yp, Xp), dtype),),
                name=f"star{r}_ring", operand_names=["src", "dst"],
                costs=CostModel(vpu_elems_per_step=fl * Y * X * Z / Zp,
                                vpu_shape=(Y, X),
                                work_per_step=float(Y * X) * Z / Zp,
                                elem_bytes=elem_bytes))
        y_alloc = (Y // ty + 1) * ty
        return KernelBuild(
            call, (arg("src", (Zp, y_alloc, Xp), dtype),),
            name=f"star{r}_ytile{ty}",
            operand_names=["src_a", "src_b", "dst"],
            costs=CostModel(vpu_elems_per_step=fl * ty * X * Z / Zp,
                            vpu_shape=(ty, X),
                            work_per_step=float(ty * X) * Z / Zp,
                            elem_bytes=elem_bytes))

    return tuple(candidates(build, _space(r, domain)))


def candidate_specs(r: int, domain: tuple, elem_bytes: int = 4):
    """Yield (config, PallasKernelSpec) for every generator decision."""
    yield from _candidates(r, tuple(domain), elem_bytes)


def traced_gpu_spec(r: int, domain: tuple, elem_bytes: int = 8):
    """GPU address expressions traced from the replane kernel body: one
    per-point Access per stencil tap (structurally identical to
    ``core.specs.star_stencil_3d``)."""
    import jax.numpy as jnp

    from repro.frontend import CostModel, arg, lower_gpu, trace_kernel

    from .kernel import make_replane

    Z, Y, X = domain
    dtype = dtype_for(elem_bytes)
    traced = trace_kernel(
        make_replane(r, tuple(domain), (1.0,) * (6 * r + 1), dtype),
        (arg("src", (Z + 2 * r, Y + 2 * r, X + 2 * r), dtype),),
        name=f"star3d_r{r}", out_names=("dst",), trace_body=True)
    return lower_gpu(traced, CostModel(flops_per_point=float(6 * r + 1)),
                     name=f"star3d_r{r}")


def rank_configs(
    r: int, domain: tuple, machine: TPUMachine = TPU_V5E, elem_bytes: int = 4
) -> list[RankedPallasConfig]:
    return select_pallas_config(candidate_specs(r, domain, elem_bytes), machine)


def generate(
    r: int,
    domain: tuple,
    weights,
    machine: TPUMachine = TPU_V5E,
    dtype=None,
    elem_bytes: int = 4,
):
    """Pick the best configuration analytically and build that kernel."""
    import jax.numpy as jnp

    from .kernel import make_kernel

    ranked = rank_configs(r, domain, machine, elem_bytes)
    if not ranked:
        raise RuntimeError("no feasible stencil configuration for this domain")
    best = ranked[0]
    cfg = best.config
    kern = make_kernel(
        cfg["variant"], r, domain, weights, dtype or jnp.float32, cfg.get("ty")
    )
    return kern, best
