"""Stencil code generator + estimator coupling (paper fig. 1, on TPU).

``candidate_configs`` enumerates the generator's decision space (variant x
tile size) and emits, for each candidate, the *address-expression artifact*
(a PallasKernelSpec) that the estimator prices — before any code exists.
``generate`` then materializes only the winning kernel.  This mirrors the
pystencils integration: the generator owns the decisions, the estimator
ranks them analytically.
"""
from __future__ import annotations

import math

from repro.core.machines import TPUMachine, TPU_V5E
from repro.core.tpu_adapt import (
    OperandSpec,
    PallasKernelSpec,
    RankedPallasConfig,
    select_pallas_config,
)


def _flops_per_point(r: int) -> float:
    return float(6 * r + 1) * 2.0  # mul + add per tap


def candidate_specs(r: int, domain: tuple, elem_bytes: int = 4):
    """Yield (config, PallasKernelSpec) for every generator decision."""
    Z, Y, X = domain
    Yp, Xp = Y + 2 * r, X + 2 * r
    Zp = Z + 2 * r
    fl = _flops_per_point(r)

    # variant A: replane
    ops_a = tuple(
        OperandSpec(f"src_p{k}", (1, Yp, Xp), elem_bytes, grid_deps=(0,))
        for k in range(2 * r + 1)
    ) + (OperandSpec("dst", (1, Y, X), elem_bytes, grid_deps=(0,), is_output=True),)
    yield (
        {"variant": "replane"},
        PallasKernelSpec(
            name=f"star{r}_replane",
            grid=(Z,),
            operands=ops_a,
            vpu_elems_per_step=fl * Y * X,
            vpu_shape=(Y, X),
            work_per_step=float(Y * X),
            elem_bytes=elem_bytes,
        ),
    )

    # variant B: ring (full planes)
    nring = 2 * r + 1
    yield (
        {"variant": "ring"},
        PallasKernelSpec(
            name=f"star{r}_ring",
            grid=(Zp,),
            operands=(
                OperandSpec("src", (1, Yp, Xp), elem_bytes, grid_deps=(0,)),
                OperandSpec("dst", (1, Y, X), elem_bytes, grid_deps=(0,), is_output=True),
            ),
            vpu_elems_per_step=fl * Y * X * Z / Zp,
            vpu_shape=(Y, X),
            scratch_bytes=nring * Yp * Xp * elem_bytes,
            work_per_step=float(Y * X) * Z / Zp,
            elem_bytes=elem_bytes,
        ),
    )

    # variant C: y-tiled ring for each feasible tile size
    ty = max(2 * r, 8)
    while ty <= Y // 2:
        if Y % ty == 0:
            yield (
                {"variant": "ytile_ring", "ty": ty},
                PallasKernelSpec(
                    name=f"star{r}_ytile{ty}",
                    grid=(Y // ty, Zp),
                    operands=(
                        OperandSpec("src_a", (1, ty, Xp), elem_bytes, grid_deps=(0, 1)),
                        OperandSpec("src_b", (1, ty, Xp), elem_bytes, grid_deps=(0, 1)),
                        OperandSpec(
                            "dst", (1, ty, X), elem_bytes, grid_deps=(0, 1), is_output=True
                        ),
                    ),
                    vpu_elems_per_step=fl * ty * X * Z / Zp,
                    vpu_shape=(ty, X),
                    scratch_bytes=nring * 2 * ty * Xp * elem_bytes,
                    work_per_step=float(ty * X) * Z / Zp,
                    elem_bytes=elem_bytes,
                ),
            )
        ty *= 2


def rank_configs(
    r: int, domain: tuple, machine: TPUMachine = TPU_V5E, elem_bytes: int = 4
) -> list[RankedPallasConfig]:
    return select_pallas_config(candidate_specs(r, domain, elem_bytes), machine)


def generate(
    r: int,
    domain: tuple,
    weights,
    machine: TPUMachine = TPU_V5E,
    dtype=None,
    elem_bytes: int = 4,
):
    """Pick the best configuration analytically and build that kernel."""
    import jax.numpy as jnp

    from .kernel import make_kernel

    ranked = rank_configs(r, domain, machine, elem_bytes)
    if not ranked:
        raise RuntimeError("no feasible stencil configuration for this domain")
    best = ranked[0]
    cfg = best.config
    kern = make_kernel(
        cfg["variant"], r, domain, weights, dtype or jnp.float32, cfg.get("ty")
    )
    return kern, best
