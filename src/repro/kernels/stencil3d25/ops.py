"""Jit'd public API for the generated star-stencil kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .generator import generate, rank_configs
from .kernel import make_kernel
from .ref import pad_input, star_weights


@functools.partial(jax.jit, static_argnames=("r", "variant", "ty", "weights"))
def _apply(src, *, weights: tuple, r: int, variant: str, ty):
    """weights are codegen constants (baked into the kernel), hence static."""
    Z, Y, X = src.shape
    padded = jnp.pad(src, ((r, r), (r, r), (r, r)))
    if variant == "ytile_ring":
        t = ty or max(2 * r, 8)
        ny = Y // t
        extra = (ny + 1) * t - (Y + 2 * r)
        padded = jnp.pad(padded, ((0, 0), (0, extra), (0, 0)))
    kern = make_kernel(variant, r, (Z, Y, X), weights, src.dtype, ty)
    return kern(padded)


def star_stencil(src, weights=None, r: int = 4, config: dict | None = None):
    """Apply the range-r star stencil; configuration chosen by the estimator
    unless ``config={'variant':..., 'ty':...}`` pins it."""
    if weights is None:
        weights = star_weights(r, src.dtype)
    w_static = tuple(float(w) for w in jax.device_get(weights))
    if config is None:
        ranked = rank_configs(r, src.shape, elem_bytes=src.dtype.itemsize)
        if not ranked:
            raise RuntimeError("no feasible config")
        config = ranked[0].config
    return _apply(
        src, weights=w_static, r=r, variant=config["variant"], ty=config.get("ty")
    )
