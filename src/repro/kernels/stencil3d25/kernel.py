"""Generated Pallas TPU kernels for the range-r 3D star stencil.

Three code-generation variants (DESIGN §3.1) whose configuration the
Warpspeed-TPU estimator selects analytically:

  * ``replane``    — naive plane streaming: 2r+1 full-plane input refs per
    step; no scratch.  The "bad but simple" configuration.
  * ``ring``       — single leading-plane ref + VMEM ring buffer of 2r+1
    planes; HBM volume is one load + one store per point (beats GPU caches —
    the software-managed layer condition).  Requires the full-plane working
    set to fit VMEM.
  * ``ytile_ring`` — ring variant with y-tiling for domains whose planes
    violate the VMEM layer condition; trades 2x halo refetch for residency.

All variants keep x/y halos in-plane via static slices of padded planes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_star(plane_at, weights, r, Y, X, y0, x0):
    """Weighted star sum given ``plane_at(dz) -> padded (yrows, Xp) plane``.

    y0/x0: offsets of the output origin inside the padded plane.
    """
    out = weights[0] * jax.lax.dynamic_slice(plane_at(0), (y0, x0), (Y, X))
    w = 1
    for axis in range(3):
        for o in range(1, r + 1):
            for s in (-o, o):
                if axis == 0:
                    sl = jax.lax.dynamic_slice(plane_at(s), (y0, x0), (Y, X))
                elif axis == 1:
                    sl = jax.lax.dynamic_slice(plane_at(0), (y0 + s, x0), (Y, X))
                else:
                    sl = jax.lax.dynamic_slice(plane_at(0), (y0, x0 + s), (Y, X))
                out = out + weights[w] * sl
                w += 1
    return out


def make_replane(r: int, domain: tuple, weights, dtype=jnp.float32):
    """Variant A: 2r+1 plane refs, no scratch."""
    Z, Y, X = domain
    Yp, Xp = Y + 2 * r, X + 2 * r
    weights = tuple(float(w) for w in weights)

    def kernel(*refs):
        planes = refs[: 2 * r + 1]
        o_ref = refs[2 * r + 1]

        def plane_at(dz):
            return planes[dz + r][0]

        o_ref[0] = _apply_star(plane_at, weights, r, Y, X, r, r)

    def call(src_padded):
        in_specs = [
            pl.BlockSpec((1, Yp, Xp), functools.partial(lambda k, t: (t + k, 0, 0), k))
            for k in range(2 * r + 1)
        ]
        return pl.pallas_call(
            kernel,
            grid=(Z,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, Y, X), lambda t: (t, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((Z, Y, X), dtype),
            interpret=_INTERPRET,
        )(*([src_padded] * (2 * r + 1)))

    return call


def make_ring(r: int, domain: tuple, weights, dtype=jnp.float32):
    """Variant B: leading-plane ref + (2r+1)-plane VMEM ring buffer."""
    Z, Y, X = domain
    Yp, Xp = Y + 2 * r, X + 2 * r
    Zp = Z + 2 * r
    nring = 2 * r + 1
    weights = tuple(float(w) for w in weights)

    def kernel(s_ref, o_ref, ring):
        t = pl.program_id(0)
        ring[t % nring] = s_ref[0]

        @pl.when(t >= 2 * r)
        def _():
            def plane_at(dz):
                # center plane is t - r (padded z coords); slot modulo ring
                return ring[(t - r + dz) % nring]

            o_ref[0] = _apply_star(plane_at, weights, r, Y, X, r, r)

    def call(src_padded):
        return pl.pallas_call(
            kernel,
            grid=(Zp,),
            in_specs=[pl.BlockSpec((1, Yp, Xp), lambda t: (t, 0, 0))],
            out_specs=pl.BlockSpec(
                (1, Y, X), lambda t: (jnp.maximum(t - 2 * r, 0), 0, 0)
            ),
            out_shape=jax.ShapeDtypeStruct((Z, Y, X), dtype),
            scratch_shapes=[pltpu.VMEM((nring, Yp, Xp), dtype)],
            interpret=_INTERPRET,
        )(src_padded)

    return call


def make_ytile_ring(r: int, domain: tuple, weights, ty: int, dtype=jnp.float32):
    """Variant C: ring buffer over y-tiles (fulfills the VMEM layer condition
    for large planes at the cost of 2x tile fetch)."""
    Z, Y, X = domain
    if Y % ty or ty < 2 * r:
        raise ValueError("ty must divide Y and be >= 2r")
    ny = Y // ty
    Xp = X + 2 * r
    Zp = Z + 2 * r
    nring = 2 * r + 1
    weights = tuple(float(w) for w in weights)
    # padded-y size must cover block j+1 (rows up to (ny+1)*ty)
    y_alloc = (ny + 1) * ty

    def kernel(a_ref, b_ref, o_ref, ring):
        t = pl.program_id(1)
        ring[t % nring] = jnp.concatenate([a_ref[0], b_ref[0]], axis=0)

        @pl.when(t >= 2 * r)
        def _():
            def plane_at(dz):
                return ring[(t - r + dz) % nring]

            o_ref[0] = _apply_star(plane_at, weights, r, ty, X, r, r)

    def call(src_padded_y):
        """src_padded_y: (Zp, y_alloc, Xp) — y padded by r at top and to
        y_alloc at the bottom (ops.py prepares this)."""
        return pl.pallas_call(
            kernel,
            grid=(ny, Zp),
            in_specs=[
                pl.BlockSpec((1, ty, Xp), lambda j, t: (t, j, 0)),
                pl.BlockSpec((1, ty, Xp), lambda j, t: (t, j + 1, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, ty, X), lambda j, t: (jnp.maximum(t - 2 * r, 0), j, 0)
            ),
            out_shape=jax.ShapeDtypeStruct((Z, Y, X), dtype),
            scratch_shapes=[pltpu.VMEM((nring, 2 * ty, Xp), dtype)],
            interpret=_INTERPRET,
        )(src_padded_y, src_padded_y)

    return call


# interpret=True: this container validates kernels on CPU; on a real TPU
# deployment flip to False (module-level so tests/benches share it).
_INTERPRET = True


VARIANTS = ("replane", "ring", "ytile_ring")


def make_kernel(variant: str, r: int, domain: tuple, weights, dtype=jnp.float32, ty=None):
    if variant == "replane":
        return make_replane(r, domain, weights, dtype)
    if variant == "ring":
        return make_ring(r, domain, weights, dtype)
    if variant == "ytile_ring":
        return make_ytile_ring(r, domain, weights, ty or max(2 * r, 8), dtype)
    raise ValueError(f"unknown variant {variant}")
