"""Versioned wire codec for the pricing service (DESIGN.md §12).

One JSON-safe serialization shared by everything that leaves the process:
the ``repro.serve`` socket protocol, ``PriceResult.to_json_dict``, and the
exact (``to_wire``) form of suite reports.  The encoding is a tagged tree
over a **whitelist** of repro dataclasses — never pickle, so a daemon only
ever materializes types this module registered:

    scalars                     -> themselves (numpy scalars -> .item())
    tuple / list                -> {"$": "tuple" | "list", "v": [...]}
    dict (any hashable keys)    -> {"$": "dict", "v": [[k, v], ...]}
    registered dataclass        -> {"$": "<ClassName>", "f": {field: ...}}

Python's ``json`` round-trips floats exactly (shortest-repr), tuples are
restored as tuples, and dataclasses rebuild through their constructors —
so ``decode(encode(x)) == x`` for every value the engine produces, and the
restored objects hash/compare identically (frozen specs keep working as
cache keys).  ``SCHEMA_VERSION`` rides in every envelope; a payload from a
newer schema is rejected, not guessed at.

``request_digest`` — sha256 over the canonical encoding — is the identity
of a request: the scheduler's memo and in-flight dedupe both key on it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.api import PlanRef, PriceRequest, PriceResult
from repro.core.access import Access, Field, KernelSpec, LaunchConfig
from repro.core.capacity import CapacityModel, HitRateFit
from repro.core.engine import (
    EvalResult,
    ExplorationReport,
    PrunedConfig,
    RejectedSpec,
    SkippedConfig,
    Workload,
)
from repro.core.machines import (
    GPUGeometry,
    GPUMachine,
    TPUGeometry,
    TPUMachine,
)
from repro.core.perfmodel import GPUEstimate, VolumeBreakdown
from repro.core.roofline import RooflineReport
from repro.core.tpu_adapt import (
    MatmulShape,
    OperandSpec,
    PallasEstimate,
    PallasKernelSpec,
)
from repro.frontend import TracedSpecPayload
from repro.suite.report import ModelReport, SuiteReport, WorkloadPricing

SCHEMA_VERSION = 1

# the whitelist: everything a PriceRequest/PriceResult tree can contain
_CLASSES = (
    PriceRequest, PriceResult, PlanRef, TracedSpecPayload,
    Workload, ExplorationReport, EvalResult, SkippedConfig, PrunedConfig,
    RejectedSpec,
    KernelSpec, Field, Access, LaunchConfig,
    GPUMachine, TPUMachine, GPUGeometry, TPUGeometry,
    CapacityModel, HitRateFit,
    GPUEstimate, VolumeBreakdown,
    PallasKernelSpec, OperandSpec, MatmulShape, PallasEstimate,
    SuiteReport, ModelReport, WorkloadPricing, RooflineReport,
)
_BY_NAME = {cls.__name__: cls for cls in _CLASSES}
_BY_CLASS = {cls: cls.__name__ for cls in _CLASSES}
_RESERVED = {"tuple", "list", "dict"}
assert not _RESERVED & set(_BY_NAME), "class name collides with a container tag"


def encode(obj):
    """Lower ``obj`` to the tagged JSON-safe tree.

    Raises ``TypeError`` for anything outside the whitelist — by design:
    a request that cannot be encoded cannot be deduped or served.
    """
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    cls = type(obj)
    if cls.__module__.startswith("numpy") and hasattr(obj, "item"):
        return encode(obj.item())
    name = _BY_CLASS.get(cls)
    if name is not None:
        return {"$": name,
                "f": {f.name: encode(getattr(obj, f.name))
                      for f in dataclasses.fields(obj)}}
    if isinstance(obj, tuple):
        return {"$": "tuple", "v": [encode(x) for x in obj]}
    if isinstance(obj, list):
        return {"$": "list", "v": [encode(x) for x in obj]}
    if isinstance(obj, dict):
        return {"$": "dict",
                "v": [[encode(k), encode(v)] for k, v in obj.items()]}
    raise TypeError(
        f"{cls.__module__}.{cls.__qualname__} is not wire-encodable "
        f"(register it in repro.serve.schema, or keep it out of the "
        f"request/result tree)")


def decode(node):
    """Rebuild the value tree ``encode`` produced."""
    if node is None or isinstance(node, (bool, int, float, str)):
        return node
    if isinstance(node, list):      # only inside a tagged container
        return [decode(x) for x in node]
    if not isinstance(node, dict):
        raise TypeError(f"malformed wire node of type {type(node).__name__}")
    tag = node.get("$")
    if tag == "tuple":
        return tuple(decode(x) for x in node["v"])
    if tag == "list":
        return [decode(x) for x in node["v"]]
    if tag == "dict":
        return {_hashable(decode(k)): decode(v) for k, v in node["v"]}
    cls = _BY_NAME.get(tag)
    if cls is None:
        raise TypeError(f"unknown wire tag {tag!r} (schema skew? this side "
                        f"speaks version {SCHEMA_VERSION})")
    return cls(**{k: decode(v) for k, v in node["f"].items()})


def _hashable(key):
    # dict keys decoded from pair lists may be lists only via the bare-list
    # branch, which tagged encoding never emits for keys; guard anyway
    return tuple(key) if isinstance(key, list) else key


def dumps(obj, **kw) -> str:
    """Versioned envelope -> compact JSON text."""
    return json.dumps({"schema_version": SCHEMA_VERSION, "body": encode(obj)},
                      separators=(",", ":"), **kw)


def loads(text: str):
    env = json.loads(text)
    if not isinstance(env, dict) or "body" not in env:
        raise ValueError("not a repro wire envelope")
    version = env.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ValueError(f"wire schema version {version} != "
                         f"{SCHEMA_VERSION} (upgrade the older side)")
    return decode(env["body"])


def request_digest(request) -> str:
    """Structural identity of a request: sha256 of its canonical encoding.

    Two requests with equal digests ask for bitwise-identical work — the
    scheduler's result memo and in-flight dedupe key on this.
    """
    text = json.dumps(encode(request), separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(text.encode()).hexdigest()


__all__ = ["SCHEMA_VERSION", "encode", "decode", "dumps", "loads",
           "request_digest"]
