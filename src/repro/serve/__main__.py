import sys

from .daemon import main

sys.exit(main())
