"""Request scheduler: dedupe, memoization, and sweep coalescing.

The daemon's brain, usable in-process too.  Every submitted
``PriceRequest`` is identified by its structural digest
(``schema.request_digest``); the scheduler then guarantees each distinct
digest is **priced at most once** while it stays memoized:

  * **memo hit** — a digest priced before resolves immediately from an LRU
    result memo (no engine work, no queue: this is the single-digit-ms warm
    path the soak benchmark gates);
  * **in-flight join** — a digest currently being priced attaches to the
    existing computation's future instead of enqueueing again (concurrent
    identical clients collapse structurally, the way suite lowering
    collapses repeated cells);
  * **coalesced sweep** — distinct queued requests with compatible sweep
    parameters (same machines/top_k/strict/machine_axis/gpu_configs, no
    suite plans) merge into ONE engine sweep under ``q<i>::`` workload
    prefixes, then split back per request — sharing the invariant cache,
    cell dedupe, and pool batching across clients.

Robustness (DESIGN.md §13):

  * **bounded queue** — with ``max_queue`` set, a submission that would
    grow the queue past the bound is rejected with ``QueueFullError``
    (carrying a ``retry_after_s`` hint) instead of queueing unboundedly;
    memo hits and in-flight joins are never rejected (they cost no sweep);
  * **per-request deadlines** — a request carrying ``deadline_s`` that
    cannot finish its exact sweep in time resolves to the tier-1
    closed-form bound ranking (``repro.api.price_bounds``) flagged
    ``degraded=True`` — an explicit, sound, cheap answer instead of a
    timeout.  Degraded results are never memoized (a later undeadlined ask
    gets the exact sweep) and deadline requests never coalesce;
  * **cancellation** — ``cancel(fut)`` detaches a waiter whose client went
    away; a queued request all of whose waiters cancelled is dropped
    before any engine work runs;
  * **durable memo** (DESIGN.md §15) — with ``memo_path`` set, every
    memoized ``[digest, wire]`` pair appends to a versioned
    :mod:`repro.durable` journal the moment it resolves, and
    ``restore_memo=True`` replays it at boot (``memo_restored`` counter) —
    so even a SIGKILL'd daemon restarts warm, losing at most the entry
    that was mid-commit.  A graceful ``shutdown`` compacts the journal to
    ``snapshot_memo()`` (header + live memo, atomically replaced).

Counters make all of this observable (and gateable): ``requests =
memo_hits + dedupe_joins + keys_priced + cancelled`` holds once the queue
drains, and the *live* form ``requests = memo_hits + dedupe_joins +
keys_priced + cancelled + pending`` holds at any instant of a ``stats()``
snapshot (``pending`` counts accepted digests not yet resolved;
``cancelled`` counts requests dropped before pricing; degraded
resolutions are ordinary ``keys_priced``).  Rejected submissions are
counted separately — they were never accepted as requests.  The counters
live in a documented ``repro.obs.metrics.CounterGroup`` (``serve.*``), so
they also surface in ``obs.metrics.snapshot()`` and the daemon's ``stats``
op.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future

from repro import durable, obs
from repro.api import PriceRequest, PriceResult, price, price_bounds
from repro.obs.metrics import CounterGroup
from repro.core.engine import (
    EvalResult,
    ExplorationReport,
    Explorer,
    PrunedConfig,
    SkippedConfig,
)

from .schema import SCHEMA_VERSION, dumps, encode, loads, request_digest

# memo journal framing (DESIGN.md §15): frame 0 is this versioned header,
# every later frame is one ``[digest, wire]`` pair appended the moment a
# digest memoizes — so even a SIGKILL'd daemon loses at most the entry that
# was mid-commit, and a ``--resume`` boot restores the warm memo verbatim
_MEMO_KIND = "repro-memo-journal"
_MEMO_VERSION = 1


def _memo_header() -> bytes:
    return json.dumps({"kind": _MEMO_KIND, "version": _MEMO_VERSION,
                       "schema_version": SCHEMA_VERSION},
                      separators=(",", ":")).encode()


class QueueFullError(RuntimeError):
    """Backpressure: the scheduler queue is at its bound.

    ``retry_after_s`` estimates when capacity should free up — clients
    (``PriceClient`` does this automatically) should back off at least
    that long and resubmit; the request digest makes the retry idempotent.
    """

    def __init__(self, message: str, retry_after_s: float = 0.1):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class DeadlineExceeded(RuntimeError):
    """Internal: raised out of the engine's progress callback to abandon
    an exact sweep whose request deadline has passed."""


class _Memo:
    """One memoized result + its lazily rendered wire text."""

    __slots__ = ("result", "wire")

    def __init__(self, result):
        self.result = result
        self.wire = None


class _Pending:
    """One in-flight digest: the request and every future joined to it.

    ``deadline`` is an absolute ``time.monotonic()`` instant (None for
    no deadline) — absolute so queue wait counts against it.
    """

    __slots__ = ("digest", "request", "futures", "deadline")

    def __init__(self, digest, request, deadline=None):
        self.digest = digest
        self.request = request
        self.futures: list = []
        self.deadline = deadline


def _coalesce_key(request: PriceRequest):
    """Requests sharing this key can merge into one sweep (suite plans are
    already one sweep internally and keep their own fold, so they never
    coalesce with others)."""
    if request.plans:
        return None
    body = encode((request.machines, request.gpu_configs, request.top_k,
                   request.strict, request.machine_axis))
    return json.dumps(body, separators=(",", ":"), sort_keys=True)


def _prefixed(request: PriceRequest, tag: str) -> PriceRequest:
    return PriceRequest(
        workloads=tuple(dataclasses.replace(w, name=f"{tag}{w.name}")
                        for w in request.workloads),
        traced=tuple(dataclasses.replace(t, name=f"{tag}{t.name}")
                     for t in request.traced),
        machines=request.machines, gpu_configs=request.gpu_configs,
        top_k=request.top_k, strict=request.strict,
        machine_axis=request.machine_axis,
    )


def _split_report(merged, tag: str) -> ExplorationReport:
    """Extract one request's rows from a coalesced report, prefix stripped.

    Estimates are the merged sweep's objects untouched — workload names are
    labels, not pricing inputs (``_cell_signature`` never reads them), so
    the split rows are bitwise identical to a solo sweep's.
    """
    n = len(tag)
    out = ExplorationReport(
        entries=[EvalResult(e.workload[n:], e.machine, e.backend, e.index,
                            e.config, e.estimate, e.perf, e.limiter)
                 for e in merged.entries if e.workload.startswith(tag)],
        skipped=[SkippedConfig(s.workload[n:], s.machine, s.config, s.reason)
                 for s in merged.skipped if s.workload.startswith(tag)],
        pruned=[PrunedConfig(p.workload[n:], p.machine, p.config, p.bound,
                             p.threshold)
                for p in merged.pruned if p.workload.startswith(tag)],
        cache_stats=dict(merged.cache_stats),
        wall_time_s=merged.wall_time_s,
        metrics=dict(merged.metrics),
    )
    out.cache_stats["coalesced"] = True
    out.metrics["serve.coalesced"] = 1
    return out


class Scheduler:
    """Thread-safe pricing scheduler over one shared ``Explorer``."""

    def __init__(self, engine: Explorer | None = None, *,
                 memo_entries: int = 1024, coalesce: bool = True,
                 max_queue: int | None = None,
                 default_deadline_s: float | None = None,
                 memo_path: str | os.PathLike | None = None,
                 restore_memo: bool = False):
        self.engine = engine or Explorer()
        self.memo_entries = memo_entries
        self.coalesce = coalesce
        self.max_queue = max_queue
        self.default_deadline_s = default_deadline_s
        self._memo: OrderedDict = OrderedDict()   # digest -> _Memo (LRU)
        self._inflight: dict = {}                 # digest -> _Pending
        self._queue: list = []                    # _Pending FIFO
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._stop = False
        self.counters = CounterGroup("serve", {
            "requests": "submissions accepted (memo + join + queued)",
            "memo_hits": "requests resolved from the result memo",
            "dedupe_joins": "requests joined to an in-flight digest",
            "keys_priced": "distinct digests priced (incl. degraded/errors)",
            "errors": "pricings that resolved to an exception",
            "coalesced_sweeps": "merged sweeps run for request groups",
            "coalesced_requests": "requests served out of merged sweeps",
            "rejected": "submissions bounced by queue backpressure",
            "degraded": "requests answered with the bound-only ranking",
            "cancelled": "queued requests dropped before any pricing",
            "memo_restored": "memo entries restored from the journal at "
                             "boot (warm restarts)",
        })
        # durable memo (DESIGN.md §15): entries journal as they memoize;
        # boot with restore_memo=True replays them, then the journal is
        # re-snapshotted so it holds exactly the live memo + header.  A
        # non-restoring boot leaves the journal's warmth intact for a
        # later --resume — it only truncates any torn tail so its own
        # appends land on the committed prefix, not behind garbage.
        self.memo_path = os.fspath(memo_path) if memo_path else None
        self._memo_journal = (durable.Journal(self.memo_path)
                              if self.memo_path else None)
        self.memo_restored = 0
        if self._memo_journal is not None:
            if restore_memo:
                self.memo_restored = self._restore_memo()
                self.snapshot_memo()
            else:
                payloads, _ = self._memo_journal.recover()
                if not payloads:        # fresh journal: header frame first
                    self.snapshot_memo()
        self._worker = threading.Thread(target=self._run, name="repro-serve",
                                        daemon=True)
        self._worker.start()

    # ---- client side ---------------------------------------------------
    def submit(self, request: PriceRequest, digest: str | None = None, *,
               deadline_s: float | None = None) -> Future:
        """Queue one request; the future resolves to its ``PriceResult``.

        ``deadline_s`` (falling back to ``default_deadline_s``) bounds the
        wall time this request may spend queued + priced; past it, the
        future resolves to a ``degraded=True`` bound ranking.  Raises
        ``QueueFullError`` when the queue is at ``max_queue`` (memo hits
        and joins are exempt — they need no queue slot).
        """
        digest = digest or request_digest(request)
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        fut: Future = Future()
        with self._wake:
            if self._stop:
                raise RuntimeError("scheduler is shut down")
            memo = self._memo.get(digest)
            if memo is not None:
                self.counters["requests"] += 1
                self.counters["memo_hits"] += 1
                self._memo.move_to_end(digest)
                fut.set_result(memo.result)
                return fut
            pending = self._inflight.get(digest)
            if pending is not None:
                self.counters["requests"] += 1
                self.counters["dedupe_joins"] += 1
                pending.futures.append(fut)
                return fut
            if self.max_queue is not None and len(self._queue) >= self.max_queue:
                # rejected before being counted as a request: the counter
                # identity covers accepted work only
                self.counters["rejected"] += 1
                raise QueueFullError(
                    f"scheduler queue is full ({self.max_queue} pending); "
                    f"retry with backoff",
                    retry_after_s=0.05 * (len(self._queue) + 1))
            self.counters["requests"] += 1
            deadline = (time.monotonic() + deadline_s
                        if deadline_s is not None else None)
            pending = _Pending(digest, request, deadline)
            pending.futures.append(fut)
            self._inflight[digest] = pending
            self._queue.append(pending)
            self._wake.notify()
        return fut

    def price_now(self, request: PriceRequest,
                  digest: str | None = None) -> PriceResult:
        """Synchronous convenience: submit and wait."""
        return self.submit(request, digest).result()

    def cancel(self, fut: Future) -> bool:
        """Detach one waiter (its client went away).

        A queued request all of whose waiters cancelled is dropped without
        pricing (counted in ``cancelled``); a request already being priced
        completes and memoizes — the work is sunk either way, and the next
        identical ask becomes a memo hit.  Returns True if ``fut`` itself
        was cancelled.
        """
        with self._wake:
            for pending in list(self._inflight.values()):
                if fut in pending.futures:
                    pending.futures.remove(fut)
                    if not pending.futures and pending in self._queue:
                        self._queue.remove(pending)
                        self._inflight.pop(pending.digest, None)
                        self.counters["cancelled"] += 1
                    break
        return fut.cancel()

    def encoded(self, digest: str, result: PriceResult) -> str:
        """Wire text for one result, rendered once per memoized digest —
        warm responses skip both the sweep AND re-serialization."""
        with self._lock:
            memo = self._memo.get(digest)
            if memo is not None and memo.wire is not None:
                return memo.wire
        from .schema import dumps

        wire = dumps(result)
        with self._lock:
            memo = self._memo.get(digest)
            if memo is not None:
                memo.wire = wire
        return wire

    # ---- durable memo (DESIGN.md §15) -----------------------------------
    def _restore_memo(self) -> int:
        """Replay the memo journal: header frame validated (kind, journal
        version, wire schema version — any mismatch means a different
        daemon wrote it, so restore nothing), then one memo entry per
        committed frame, capped at ``memo_entries``.  Torn tails were
        already truncated/quarantined by the journal recovery."""
        with obs.span("durable.recover", cat="serve", path=self.memo_path):
            payloads, _ = self._memo_journal.recover()
            if not payloads:
                return 0
            try:
                hdr = json.loads(payloads[0])
                ok = (isinstance(hdr, dict)
                      and hdr.get("kind") == _MEMO_KIND
                      and hdr.get("version") == _MEMO_VERSION
                      and hdr.get("schema_version") == SCHEMA_VERSION)
            except Exception:
                ok = False
            if not ok:
                return 0
            restored = 0
            for raw in payloads[1:]:
                if len(self._memo) >= self.memo_entries:
                    break
                try:
                    digest, wire = json.loads(raw)
                    memo = _Memo(loads(wire))
                    memo.wire = wire
                except Exception:
                    continue
                self._memo[digest] = memo
                restored += 1
            self.counters["memo_restored"] += restored
            return restored

    def snapshot_memo(self) -> int:
        """Atomically rewrite the memo journal as header + the live memo —
        the versioned snapshot a graceful drain persists (also run at boot
        so the journal never carries stale or foreign frames forward).
        Returns the number of entries snapshotted."""
        if self._memo_journal is None:
            return 0
        with self._lock:
            items = list(self._memo.items())
        entries = []
        for digest, memo in items:
            try:
                wire = memo.wire or dumps(memo.result)
                entries.append(json.dumps([digest, wire],
                                          separators=(",", ":")).encode())
            except Exception:
                continue
        try:
            self._memo_journal.rewrite([_memo_header()] + entries)
        except OSError:
            return 0
        return len(entries)

    def stats(self) -> dict:
        with self._lock:
            out = dict(self.counters)
            out["memo_entries"] = len(self._memo)
            out["inflight"] = len(self._inflight) + len(self._queue)
            # accepted digests not yet priced/cancelled — closes the live
            # counter identity: requests == memo_hits + dedupe_joins +
            # keys_priced + cancelled + pending at any instant (the lock
            # makes counters and the in-flight table one atomic snapshot)
            out["pending"] = len(self._inflight)
        out["engine_cache"] = self.engine.cache.stats()
        out["metrics"] = obs.metrics.snapshot()
        return out

    def shutdown(self, wait: bool = True,
                 timeout: float | None = None) -> bool:
        """Stop accepting work; drain what is queued, then exit the worker
        and persist the engine's invariant cache.  Returns False when the
        worker failed to drain within ``timeout`` (it is a daemon thread,
        so a stuck engine cannot wedge interpreter exit — but callers
        should surface the failure; ``PricingDaemon`` does)."""
        with self._wake:
            self._stop = True
            self._wake.notify_all()
        drained = True
        if wait:
            self._worker.join(timeout)
            drained = not self._worker.is_alive()
        self.engine.save_cache()
        # an empty-memo drain that restored nothing has nothing to
        # snapshot — rewriting would wipe warmth a later --resume wants
        if self._memo or self.memo_restored:
            self.snapshot_memo()
        return drained

    # ---- worker side ---------------------------------------------------
    def _run(self):
        while True:
            with self._wake:
                while not self._queue and not self._stop:
                    self._wake.wait()
                if not self._queue and self._stop:
                    return
                batch, self._queue = self._queue, []
            self._serve_batch(batch)

    def _serve_batch(self, batch):
        groups: dict = {}
        solo: list = []
        if self.coalesce and len(batch) > 1:
            for p in batch:
                # deadline requests stay solo: a merged sweep would couple
                # their degradation decision to unrelated requests.  Fully
                # cancelled pendings also stay solo (served as a no-op).
                key = (None if p.deadline is not None or not p.futures
                       else _coalesce_key(p.request))
                if key is None:
                    solo.append(p)
                else:
                    groups.setdefault(key, []).append(p)
            merged_groups = [g for g in groups.values() if len(g) > 1]
            solo.extend(p for g in groups.values() if len(g) == 1 for p in g)
        else:
            merged_groups, solo = [], list(batch)
        for group in merged_groups:
            self._serve_coalesced(group)
        for p in solo:
            self._serve_one(p)

    def _serve_one(self, pending):
        if not pending.futures:
            # every waiter cancelled after this pending left the queue in a
            # worker batch — drop it without engine work
            with self._lock:
                self._inflight.pop(pending.digest, None)
                self.counters["cancelled"] += 1
            return
        deadline = pending.deadline
        if deadline is not None and time.monotonic() >= deadline:
            self._serve_degraded(pending)
            return
        progress = None
        if deadline is not None:
            def progress(done, total):
                if time.monotonic() >= deadline:
                    raise DeadlineExceeded(
                        f"deadline passed at {done}/{total} configs")
        try:
            with obs.span("serve.price", "serve",
                          digest=pending.digest[:12]):
                result = price(pending.request, engine=self.engine,
                               progress=progress)
        except DeadlineExceeded:
            self._serve_degraded(pending)
        except BaseException as exc:
            self._resolve(pending, None, exc)
        else:
            self._resolve(pending, result, None)

    def _serve_degraded(self, pending):
        """Deadline blown: answer with the closed-form bound ranking,
        explicitly flagged, instead of timing out or going silent."""
        try:
            with obs.span("serve.degraded", "serve",
                          digest=pending.digest[:12]):
                result = price_bounds(pending.request, engine=self.engine)
        except BaseException as exc:
            self._resolve(pending, None, exc)
            return
        with self._lock:
            self.counters["degraded"] += 1
        # not memoized: the next undeadlined ask deserves the exact sweep
        self._resolve(pending, result, None, memoize=False)

    def _serve_coalesced(self, group):
        tmpl = group[0].request
        merged_request = PriceRequest(
            workloads=tuple(
                w for i, p in enumerate(group)
                for w in _prefixed(p.request, f"q{i}::").workloads),
            traced=tuple(
                t for i, p in enumerate(group)
                for t in _prefixed(p.request, f"q{i}::").traced),
            machines=tmpl.machines, gpu_configs=tmpl.gpu_configs,
            top_k=tmpl.top_k, strict=tmpl.strict,
            machine_axis=tmpl.machine_axis,
        )
        try:
            with obs.span("serve.coalesce", "serve", requests=len(group)):
                merged = price(merged_request, engine=self.engine)
        except BaseException as exc:
            for p in group:
                self._resolve(p, None, exc)
            return
        with self._lock:
            self.counters["coalesced_sweeps"] += 1
            self.counters["coalesced_requests"] += len(group)
        for i, p in enumerate(group):
            report = _split_report(merged.report, f"q{i}::")
            self._resolve(p, PriceResult(report=report), None)

    def _resolve(self, pending, result, exc, memoize: bool = True):
        # durable memo: render the wire text eagerly (outside the lock —
        # it costs a serialization) so the journal frame and the lazily
        # cached memo.wire are one and the same bytes
        wire = None
        if exc is None and memoize and self._memo_journal is not None:
            try:
                wire = dumps(result)
            except Exception:
                wire = None
        with self._lock:
            self._inflight.pop(pending.digest, None)
            self.counters["keys_priced"] += 1
            if exc is None:
                if memoize:
                    memo = _Memo(result)
                    memo.wire = wire
                    self._memo[pending.digest] = memo
                    while len(self._memo) > self.memo_entries:
                        self._memo.popitem(last=False)
            else:
                self.counters["errors"] += 1
            futures = list(pending.futures)
        if wire is not None:
            # the commit point for this digest's warm-restart durability;
            # a failed append only costs warmth, never correctness
            try:
                self._memo_journal.append(
                    json.dumps([pending.digest, wire],
                               separators=(",", ":")).encode())
            except OSError:
                pass
        for fut in futures:
            if fut.cancelled():
                continue
            try:
                if exc is None:
                    fut.set_result(result)
                else:
                    fut.set_exception(exc)
            except Exception:  # noqa: BLE001 — racing client cancellation
                pass


__all__ = ["Scheduler", "QueueFullError", "DeadlineExceeded"]
