"""The pricing daemon: a long-lived estimator behind a Unix socket.

``python -m repro.serve --socket /tmp/repro.sock --cache-path ~/.repro.inv``
starts one process that loads the ``InvariantCache`` (and, through it, the
memoized stream tables) once and serves every code-generation run on the
machine.  Protocol: newline-delimited JSON over a local stream socket, one
message per line, every line carrying ``schema_version``.

Client -> server ops:
    {"op": "price", "id": <any>, "request": <encoded PriceRequest>}
    {"op": "stats"} | {"op": "ping"} | {"op": "shutdown"}

Server -> client lines:
    {"ok": true, "op": "result", "id": ..., "digest": ..., "result": ...}
    {"ok": true, "op": "stats"/"pong"/"bye", ...}
    {"ok": false, "id": ..., "error": "..."}

A connection may pipeline many ``price`` ops; results stream back **as
they complete** (matched by ``id``, not by order) — a memo-hit answer for
request 50 does not wait behind a cold sweep for request 1.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import socketserver
import threading

from repro.core.engine import Explorer

from .scheduler import Scheduler
from .schema import SCHEMA_VERSION, decode, encode, request_digest


def _line(payload: dict) -> bytes:
    payload.setdefault("schema_version", SCHEMA_VERSION)
    return (json.dumps(payload, separators=(",", ":")) + "\n").encode()


class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        server: PricingDaemon = self.server  # type: ignore[assignment]
        write_lock = threading.Lock()

        def send(payload: dict):
            data = _line(payload)
            with write_lock:
                try:
                    self.wfile.write(data)
                    self.wfile.flush()
                except (BrokenPipeError, OSError):
                    pass

        for raw in self.rfile:
            raw = raw.strip()
            if not raw:
                continue
            try:
                msg = json.loads(raw)
                op = msg.get("op")
            except Exception as exc:
                send({"ok": False, "error": f"bad message: {exc}"})
                continue
            if op == "ping":
                send({"ok": True, "op": "pong"})
            elif op == "stats":
                send({"ok": True, "op": "stats",
                      "stats": server.scheduler.stats()})
            elif op == "shutdown":
                send({"ok": True, "op": "bye"})
                server.request_shutdown()
                return
            elif op == "price":
                self._price(server, msg, send)
            else:
                send({"ok": False, "id": msg.get("id"),
                      "error": f"unknown op {op!r}"})

    def _price(self, server, msg, send):
        req_id = msg.get("id")
        try:
            version = msg.get("schema_version")
            if version != SCHEMA_VERSION:
                raise ValueError(f"schema version {version} != "
                                 f"{SCHEMA_VERSION}")
            request = decode(msg["request"])
            digest = request_digest(request)
        except Exception as exc:
            send({"ok": False, "id": req_id,
                  "error": f"{type(exc).__name__}: {exc}"})
            return

        def on_done(fut):
            try:
                result = fut.result()
            except Exception as exc:
                send({"ok": False, "id": req_id, "digest": digest,
                      "error": f"{type(exc).__name__}: {exc}"})
                return
            # memoized wire rendering: warm answers re-send cached text
            wire = server.scheduler.encoded(digest, result)
            body = json.loads(wire)["body"]
            send({"ok": True, "op": "result", "id": req_id,
                  "digest": digest, "result": body})

        try:
            server.scheduler.submit(request, digest).add_done_callback(on_done)
        except RuntimeError as exc:      # shutting down
            send({"ok": False, "id": req_id, "error": str(exc)})


class PricingDaemon(socketserver.ThreadingUnixStreamServer):
    """Threaded Unix-socket server wrapping one shared ``Scheduler``."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, socket_path: str, *, engine: Explorer | None = None,
                 scheduler: Scheduler | None = None, memo_entries: int = 1024):
        self.socket_path = os.fspath(socket_path)
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)
        self.scheduler = scheduler or Scheduler(engine,
                                                memo_entries=memo_entries)
        self._shutdown_requested = threading.Event()
        super().__init__(self.socket_path, _Handler)

    def request_shutdown(self):
        """Asynchronous clean-exit request (the ``shutdown`` op)."""
        if not self._shutdown_requested.is_set():
            self._shutdown_requested.set()
            threading.Thread(target=self.shutdown, daemon=True).start()

    def close(self):
        """Stop serving, drain the scheduler, persist the cache."""
        self.server_close()
        self.scheduler.shutdown(wait=True)
        try:
            os.unlink(self.socket_path)
        except OSError:
            pass

    # context manager: `with PricingDaemon(...) as d:` serves in background
    def __enter__(self):
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self.shutdown()
        self._thread.join(timeout=10)
        self.close()
        return False


def serve(socket_path: str, **daemon_kw) -> None:
    """Blocking entry point used by ``python -m repro.serve``."""
    daemon = PricingDaemon(socket_path, **daemon_kw)
    try:
        daemon.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        daemon.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="long-lived analytical-pricing daemon")
    ap.add_argument("--socket", default="/tmp/repro-serve.sock",
                    help="Unix socket path (default %(default)s)")
    ap.add_argument("--cache-path", default=None,
                    help="persist the invariant cache here (warm restarts)")
    ap.add_argument("--parallel", action="store_true",
                    help="evaluate structural tasks in a worker pool")
    ap.add_argument("--max-workers", type=int, default=None)
    ap.add_argument("--cache-max-entries", type=int, default=None)
    ap.add_argument("--cache-max-bytes", type=int, default=None)
    ap.add_argument("--memo-entries", type=int, default=1024,
                    help="result-memo LRU size (default %(default)s)")
    args = ap.parse_args(argv)
    engine = Explorer(parallel=args.parallel, max_workers=args.max_workers,
                      cache_path=args.cache_path,
                      cache_max_entries=args.cache_max_entries,
                      cache_max_bytes=args.cache_max_bytes)
    print(f"repro.serve: listening on {args.socket} "
          f"(cache: {args.cache_path or 'in-memory'}, "
          f"{engine.cache.loaded_entries} entries warm)")
    serve(args.socket, engine=engine, memo_entries=args.memo_entries)
    return 0


# client availability probe used by tests/benches
def can_bind_unix_sockets(tmpdir: str) -> bool:
    path = os.path.join(tmpdir, "probe.sock")
    try:
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.bind(path)
        s.close()
        os.unlink(path)
        return True
    except OSError:
        return False


__all__ = ["PricingDaemon", "serve", "main", "can_bind_unix_sockets"]
