"""The pricing daemon: a long-lived estimator behind a Unix socket.

``python -m repro.serve --socket /tmp/repro.sock --cache-path ~/.repro.inv``
starts one process that loads the ``InvariantCache`` (and, through it, the
memoized stream tables) once and serves every code-generation run on the
machine.  Protocol: newline-delimited JSON over a local stream socket, one
message per line, every line carrying ``schema_version``.

Client -> server ops:
    {"op": "price", "id": <any>, "request": <encoded PriceRequest>,
     "deadline_s": <optional seconds>}
    {"op": "stats"} | {"op": "trace"} | {"op": "ping"} | {"op": "shutdown"}

Server -> client lines:
    {"ok": true, "op": "result", "id": ..., "digest": ..., "result": ...}
    {"ok": true, "op": "stats"/"pong"/"bye", ...}
    {"ok": true, "op": "trace", "enabled": ..., "trace": <Chrome JSON>}
    {"ok": false, "id": ..., "error": "...", "error_class": "...",
     "retry_after_s": <only on backpressure rejections>}

``stats`` carries the scheduler's live counters plus the process-wide
``obs.metrics`` snapshot; ``trace`` ships the daemon's collected span
timeline as Chrome trace-event JSON (empty while telemetry is disabled —
start with ``--trace-out`` or ``REPRO_TRACE_OUT`` to collect).

A connection may pipeline many ``price`` ops; results stream back **as
they complete** (matched by ``id``, not by order) — a memo-hit answer for
request 50 does not wait behind a cold sweep for request 1.

Failure model (DESIGN.md §13): every error line names the server-side
exception class so clients can distinguish retryable conditions
(``QueueFullError`` backpressure) from permanent ones; a client that
disconnects mid-request has its outstanding submissions cancelled, so an
abandoned cold sweep still queued never runs; and shutdown is honest — a
serve or scheduler thread that fails to drain raises/exits nonzero instead
of silently leaking.

Durability (DESIGN.md §15): SIGTERM/SIGINT trigger the same graceful drain
as the ``shutdown`` op — stop accepting, finish or cancel queued work,
persist the invariant cache and a versioned scheduler-memo snapshot.
``--resume`` replays both journals on boot (plus the sweep checkpoint
journal at ``<cache-path>.sweeps``), so restarts are zero-warm-loss even
after a SIGKILL; ``--pid-file`` lets supervisors target the process.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import socketserver
import sys
import threading

from repro import durable, faults, obs
from repro.core.engine import Explorer

from .scheduler import QueueFullError, Scheduler
from .schema import SCHEMA_VERSION, decode, encode, request_digest


def _line(payload: dict) -> bytes:
    payload.setdefault("schema_version", SCHEMA_VERSION)
    return (json.dumps(payload, separators=(",", ":")) + "\n").encode()


class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        server: PricingDaemon = self.server  # type: ignore[assignment]
        write_lock = threading.Lock()
        submitted: list = []    # futures owned by this connection

        def send(payload: dict):
            if faults.drop_point("serve.socket_drop"):
                # injected connection loss: sever this client mid-response
                # (its retry path must recover; see bench_chaos_soak)
                try:
                    self.connection.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                return
            data = _line(payload)
            with write_lock:
                try:
                    self.wfile.write(data)
                    self.wfile.flush()
                except (BrokenPipeError, OSError, ValueError):
                    # client gone (ValueError: wfile already closed after
                    # the handler returned) — nobody is listening
                    pass

        try:
            for raw in self.rfile:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    msg = json.loads(raw)
                    op = msg.get("op")
                except Exception as exc:
                    send({"ok": False, "error": f"bad message: {exc}",
                          "error_class": type(exc).__name__})
                    continue
                # the span covers dispatch (for `price`: decode + submit;
                # the sweep itself runs under the scheduler's serve.* spans)
                with obs.span("daemon.op", "serve", op=str(op)):
                    if op == "ping":
                        send({"ok": True, "op": "pong"})
                    elif op == "stats":
                        send({"ok": True, "op": "stats",
                              "stats": server.scheduler.stats()})
                    elif op == "trace":
                        send({"ok": True, "op": "trace",
                              "enabled": obs.enabled(),
                              "trace": obs.chrome_trace()})
                    elif op == "shutdown":
                        send({"ok": True, "op": "bye"})
                        server.request_shutdown()
                        return
                    elif op == "price":
                        self._price(server, msg, send, submitted)
                    else:
                        send({"ok": False, "id": msg.get("id"),
                              "error": f"unknown op {op!r}",
                              "error_class": "ValueError"})
        finally:
            # client gone: detach every future this connection still owns —
            # a queued request nobody is waiting for must not burn a sweep
            for fut in submitted:
                if not fut.done():
                    server.scheduler.cancel(fut)

    def _price(self, server, msg, send, submitted):
        req_id = msg.get("id")
        try:
            version = msg.get("schema_version")
            if version != SCHEMA_VERSION:
                raise ValueError(f"schema version {version} != "
                                 f"{SCHEMA_VERSION}")
            request = decode(msg["request"])
            digest = request_digest(request)
            deadline_s = msg.get("deadline_s")
            if deadline_s is not None:
                deadline_s = float(deadline_s)
        except Exception as exc:
            send({"ok": False, "id": req_id,
                  "error": f"{type(exc).__name__}: {exc}",
                  "error_class": type(exc).__name__})
            return

        def on_done(fut):
            if fut.cancelled():
                return              # client already hung up
            try:
                result = fut.result()
            except Exception as exc:
                send({"ok": False, "id": req_id, "digest": digest,
                      "error": f"{type(exc).__name__}: {exc}",
                      "error_class": type(exc).__name__})
                return
            # memoized wire rendering: warm answers re-send cached text
            wire = server.scheduler.encoded(digest, result)
            body = json.loads(wire)["body"]
            send({"ok": True, "op": "result", "id": req_id,
                  "digest": digest, "result": body})

        try:
            fut = server.scheduler.submit(request, digest,
                                          deadline_s=deadline_s)
        except QueueFullError as exc:    # backpressure: explicit + retryable
            send({"ok": False, "id": req_id, "digest": digest,
                  "error": str(exc), "error_class": "QueueFullError",
                  "retry_after_s": exc.retry_after_s})
            return
        except RuntimeError as exc:      # shutting down
            send({"ok": False, "id": req_id, "error": str(exc),
                  "error_class": type(exc).__name__})
            return
        submitted.append(fut)
        fut.add_done_callback(on_done)


class PricingDaemon(socketserver.ThreadingUnixStreamServer):
    """Threaded Unix-socket server wrapping one shared ``Scheduler``."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, socket_path: str, *, engine: Explorer | None = None,
                 scheduler: Scheduler | None = None, memo_entries: int = 1024,
                 join_timeout_s: float = 10.0):
        self.socket_path = os.fspath(socket_path)
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)
        self.scheduler = scheduler or Scheduler(engine,
                                                memo_entries=memo_entries)
        self.join_timeout_s = join_timeout_s
        self._shutdown_requested = threading.Event()
        super().__init__(self.socket_path, _Handler)

    def request_shutdown(self):
        """Asynchronous clean-exit request (the ``shutdown`` op)."""
        if not self._shutdown_requested.is_set():
            self._shutdown_requested.set()
            threading.Thread(target=self.shutdown, daemon=True).start()

    def close(self) -> bool:
        """Stop serving, drain the scheduler, persist cache + memo.

        The graceful-drain path (DESIGN.md §15): stop accepting
        connections, let the scheduler finish or cancel queued work, then
        persist the invariant cache and the memo snapshot so the next boot
        (``--resume``) starts warm.  Returns False when the scheduler
        worker failed to drain within ``join_timeout_s`` (logged to
        stderr) — ``serve``/``main`` turn that into a nonzero exit.
        """
        with obs.span("serve.drain", "serve"):
            self.server_close()
            drained = self.scheduler.shutdown(wait=True,
                                              timeout=self.join_timeout_s)
        if not drained:
            print(f"repro.serve: scheduler worker still running after "
                  f"{self.join_timeout_s}s drain timeout; cache saved, "
                  f"worker abandoned", file=sys.stderr)
        try:
            os.unlink(self.socket_path)
        except OSError:
            pass
        return drained

    # context manager: `with PricingDaemon(...) as d:` serves in background
    def __enter__(self):
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self.shutdown()
        self._thread.join(timeout=self.join_timeout_s)
        stuck = self._thread.is_alive()
        drained = self.close()
        if stuck:
            # never swallow a wedged serve thread: the caller believes the
            # daemon is gone while it still holds the socket/scheduler
            raise RuntimeError(
                f"serve thread still alive {self.join_timeout_s}s after "
                f"shutdown; a handler is wedged")
        if not drained and exc == (None, None, None):
            raise RuntimeError(
                f"scheduler worker failed to drain within "
                f"{self.join_timeout_s}s at daemon exit")
        return False


def serve(socket_path: str, *, install_signals: bool = False,
          **daemon_kw) -> bool:
    """Blocking entry point used by ``python -m repro.serve``.

    With ``install_signals`` (only valid from the main thread), SIGTERM
    and SIGINT trigger the same graceful drain as the ``shutdown`` op:
    stop accepting, finish or cancel queued work, persist cache + memo
    snapshot — so supervisors restarting the daemon lose no warmth.
    Returns True on a clean drain, False when shutdown left a wedged
    worker behind (``main`` exits nonzero so supervisors notice).
    """
    daemon = PricingDaemon(socket_path, **daemon_kw)
    if install_signals:
        def _drain(signum, frame):
            daemon.request_shutdown()

        signal.signal(signal.SIGTERM, _drain)
        signal.signal(signal.SIGINT, _drain)
    try:
        daemon.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        clean = daemon.close()
    return clean


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="long-lived analytical-pricing daemon")
    ap.add_argument("--socket", default="/tmp/repro-serve.sock",
                    help="Unix socket path (default %(default)s)")
    ap.add_argument("--cache-path", default=None,
                    help="persist the invariant cache here (warm restarts)")
    ap.add_argument("--parallel", action="store_true",
                    help="evaluate structural tasks in a worker pool")
    ap.add_argument("--max-workers", type=int, default=None)
    ap.add_argument("--cache-max-entries", type=int, default=None)
    ap.add_argument("--cache-max-bytes", type=int, default=None)
    ap.add_argument("--memo-entries", type=int, default=1024,
                    help="result-memo LRU size (default %(default)s)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the request queue; beyond it submissions "
                         "are rejected with retry-after backpressure")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline; past it requests "
                         "degrade to the closed-form bound ranking")
    ap.add_argument("--trace-out", default=None,
                    help="collect telemetry spans and write a Chrome "
                         "trace-event JSON here on exit (live timelines "
                         "via the 'trace' op)")
    ap.add_argument("--memo-path", default=None,
                    help="journal the scheduler result memo here (default "
                         "<cache-path>.memo when --resume is set): entries "
                         "append as they memoize, so even a SIGKILL'd "
                         "daemon restarts warm")
    ap.add_argument("--resume", action="store_true",
                    help="restore durable state on boot: replay the memo "
                         "journal and the sweep checkpoint journal "
                         "(<cache-path>.sweeps), so a restarted daemon "
                         "answers memoized digests warm and never "
                         "re-prices cells a killed sweep completed")
    ap.add_argument("--pid-file", default=None,
                    help="write the daemon pid here (atomic, removed on "
                         "exit) so supervisors and the CI smoke job can "
                         "target restarts")
    args = ap.parse_args(argv)
    if args.trace_out:
        obs.enable()
    memo_path = args.memo_path
    resume_path = None
    if args.resume and args.cache_path:
        memo_path = memo_path or args.cache_path + ".memo"
        resume_path = args.cache_path + ".sweeps"
    engine = Explorer(parallel=args.parallel, max_workers=args.max_workers,
                      cache_path=args.cache_path,
                      cache_max_entries=args.cache_max_entries,
                      cache_max_bytes=args.cache_max_bytes,
                      resume=resume_path)
    scheduler = Scheduler(engine, memo_entries=args.memo_entries,
                          max_queue=args.max_queue,
                          default_deadline_s=args.deadline_s,
                          memo_path=memo_path, restore_memo=args.resume)
    if args.pid_file:
        durable.atomic_write(args.pid_file, f"{os.getpid()}\n")
    print(f"repro.serve: listening on {args.socket} "
          f"(cache: {args.cache_path or 'in-memory'}, "
          f"{engine.cache.loaded_entries} entries warm, "
          f"{scheduler.memo_restored} memo entries restored)")
    try:
        clean = serve(args.socket, scheduler=scheduler,
                      install_signals=True)
    finally:
        if args.pid_file:
            try:
                os.unlink(args.pid_file)
            except OSError:
                pass
    if args.trace_out and obs.spans():
        obs.write_trace(args.trace_out)
        print(f"repro.serve: trace written to {args.trace_out}")
    return 0 if clean else 1


# client availability probe used by tests/benches
def can_bind_unix_sockets(tmpdir: str) -> bool:
    path = os.path.join(tmpdir, "probe.sock")
    try:
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.bind(path)
        s.close()
        os.unlink(path)
        return True
    except OSError:
        return False


__all__ = ["PricingDaemon", "serve", "main", "can_bind_unix_sockets"]
