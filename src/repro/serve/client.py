"""Client for the pricing daemon.

    from repro.api import gpu_request
    from repro.serve.client import PriceClient

    with PriceClient("/tmp/repro-serve.sock") as c:
        result = c.price(gpu_request(spec, "A100", top_k=5))
        print(result.report.comparison_table())

``price_many`` pipelines a batch over one connection and yields results to
``on_result`` as the daemon streams them back (completion order), while the
returned list preserves request order.

Retries (DESIGN.md §13): with ``retries=N`` the client survives dropped
connections and ``QueueFullError`` backpressure by reconnecting and
resubmitting only the requests still unanswered, after a jittered
exponential backoff (honoring the server's ``retry_after_s`` hint).  The
retry is idempotent by construction: requests are identified server-side
by their structural ``request_digest``, so a resubmission of work the
daemon already finished (or still has in flight) resolves as a memo hit or
in-flight join — never a duplicate sweep — and results already delivered
to ``on_result`` are never delivered twice.

The same retry budget rides daemon *restart windows* (DESIGN.md §15): a
connection refused or reset while the daemon is down is just another
retryable failure, so a client with ``retries > 0`` constructed against a
dead socket — or mid-``price_many`` when the daemon is killed — reconnects
with backoff and completes once the daemon is back (warm, via its memo
journal).  Only with ``retries=0`` does construction require a live daemon.
"""
from __future__ import annotations

import json
import os
import random
import socket
import threading
import time

from repro.api import PriceRequest, PriceResult

from .schema import SCHEMA_VERSION, decode, encode, request_digest

# server-side error classes that a retry can plausibly cure
_RETRYABLE = frozenset({"QueueFullError", "ConnectionClosed"})

_MAX_BACKOFF_S = 5.0


class ServeError(RuntimeError):
    """An error line from the daemon (bad request, engine failure, skew).

    ``error_class`` names the server-side exception class (None for
    transport-level failures the client synthesizes itself);
    ``retry_after_s`` carries the server's backpressure hint when present.
    """

    def __init__(self, message: str, *, error_class: str | None = None,
                 retry_after_s: float | None = None):
        super().__init__(message)
        self.error_class = error_class
        self.retry_after_s = retry_after_s

    @property
    def retryable(self) -> bool:
        return self.error_class in _RETRYABLE


class PriceClient:
    def __init__(self, socket_path: str, *, timeout: float | None = None,
                 retries: int = 0, backoff_s: float = 0.05):
        self._path = os.fspath(socket_path)
        self._timeout = timeout
        self.retries = retries
        self.backoff_s = backoff_s
        self._send_lock = threading.Lock()
        self._next_id = 0
        self._sock: socket.socket | None = None
        self._rfile = None
        self._closed = False
        # With a retry budget, a refused connect is deferred to the first
        # op's retry loop — the daemon may be mid-restart right now.
        try:
            self._connect()
        except OSError:
            if self.retries <= 0:
                raise

    # ---- connection lifecycle ------------------------------------------
    def _connect(self) -> None:
        """Open the socket, closing it again on ANY failure — a refused or
        timed-out connect must not leak the half-built fd."""
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            if self._timeout is not None:
                sock.settimeout(self._timeout)
            sock.connect(self._path)
            rfile = sock.makefile("rb")
        except BaseException:
            sock.close()
            raise
        self._sock, self._rfile = sock, rfile

    def _reconnect(self) -> None:
        # an internal redial, not a user close — leave the client usable
        self.close()
        self._closed = False
        self._connect()

    def close(self) -> None:
        """Idempotent: safe after a failed connect and safe to call twice."""
        self._closed = True
        rfile, sock = self._rfile, self._sock
        self._rfile = self._sock = None
        try:
            if rfile is not None:
                rfile.close()
        finally:
            if sock is not None:
                sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ---- wire plumbing -------------------------------------------------
    def _send(self, payload: dict) -> None:
        if self._sock is None:
            raise OSError("client is closed")
        payload.setdefault("schema_version", SCHEMA_VERSION)
        data = (json.dumps(payload, separators=(",", ":")) + "\n").encode()
        with self._send_lock:
            self._sock.sendall(data)

    def _recv(self) -> dict:
        line = self._rfile.readline()
        if not line:
            raise ServeError("daemon closed the connection",
                             error_class="ConnectionClosed")
        return json.loads(line)

    def _take_id(self) -> int:
        with self._send_lock:
            self._next_id += 1
            return self._next_id

    # ---- ops -----------------------------------------------------------
    def ping(self) -> bool:
        self._send({"op": "ping"})
        return self._recv().get("op") == "pong"

    def stats(self) -> dict:
        self._send({"op": "stats"})
        msg = self._recv()
        if not msg.get("ok"):
            raise _error_from(msg, "stats failed")
        return msg["stats"]

    def trace(self) -> dict:
        """The daemon's span timeline as Chrome trace-event JSON
        (``{"traceEvents": [...]}``; empty while telemetry is disabled
        server-side)."""
        self._send({"op": "trace"})
        msg = self._recv()
        if not msg.get("ok"):
            raise _error_from(msg, "trace failed")
        return msg["trace"]

    def shutdown_server(self) -> None:
        self._send({"op": "shutdown"})
        try:
            self._recv()
        except ServeError:
            pass

    def price(self, request: PriceRequest,
              deadline_s: float | None = None) -> PriceResult:
        """Price one request, blocking until its result streams back.

        ``deadline_s`` bounds server-side work: past it the daemon answers
        with the closed-form bound ranking flagged ``degraded=True``.
        """
        return self.price_many([request], deadline_s=deadline_s)[0]

    def price_many(self, requests, on_result=None,
                   deadline_s: float | None = None) -> list:
        """Pipeline a batch; returns results in request order.

        ``on_result(index, result)`` fires in the daemon's completion
        order — a warm (memoized) answer arrives without waiting for cold
        sweeps submitted before it — and fires exactly once per request
        even across retries.
        """
        if self._closed:
            raise OSError("client is closed")
        requests = list(requests)
        out: list = [None] * len(requests)
        done = [False] * len(requests)
        # digests key the retry: the server dedupes resubmissions on them
        digests = [request_digest(r) for r in requests]
        attempt = 0
        while True:
            try:
                self._attempt(requests, out, done, on_result, deadline_s)
                return out
            except (ServeError, OSError) as exc:
                retryable = (isinstance(exc, OSError)
                             or (isinstance(exc, ServeError)
                                 and exc.retryable))
                if not retryable or attempt >= self.retries:
                    raise
                attempt += 1
                time.sleep(self._retry_delay(exc, digests, attempt))
                try:
                    self._reconnect()
                except OSError:
                    if attempt >= self.retries:
                        raise

    def _attempt(self, requests, out, done, on_result, deadline_s) -> None:
        """One submission round over the current connection: send every
        still-unanswered request, then drain until each has an answer."""
        if self._sock is None:      # deferred or dropped connect
            self._connect()
        ids = {}
        for i, request in enumerate(requests):
            if done[i]:
                continue
            rid = self._take_id()
            ids[rid] = i
            msg = {"op": "price", "id": rid, "request": encode(request)}
            if deadline_s is not None:
                msg["deadline_s"] = deadline_s
            self._send(msg)
        first_error = None
        while ids:
            msg = self._recv()
            rid = msg.get("id")
            if rid not in ids:
                continue            # e.g. an interleaved pong
            i = ids.pop(rid)
            if not msg.get("ok"):
                err = _error_from(msg, "pricing failed")
                if err.retryable:
                    raise err       # resubmit the unanswered remainder
                first_error = first_error or err
                continue
            result = decode(msg["result"])
            out[i] = result
            done[i] = True
            if on_result is not None:
                on_result(i, result)
        if first_error is not None:
            raise first_error

    def _retry_delay(self, exc, digests, attempt) -> float:
        """Jittered exponential backoff, keyed on the request digests so
        two clients retrying the same burst do not stampede in lock-step,
        floored at the server's explicit retry-after hint."""
        seed = f"{digests[0] if digests else ''}:{attempt}"
        rng = random.Random(seed)
        delay = self.backoff_s * (2 ** (attempt - 1)) * (0.5 + rng.random())
        hinted = getattr(exc, "retry_after_s", None)
        if hinted:
            delay = max(delay, float(hinted))
        return min(delay, _MAX_BACKOFF_S)


def _error_from(msg: dict, fallback: str) -> ServeError:
    return ServeError(msg.get("error", fallback),
                      error_class=msg.get("error_class"),
                      retry_after_s=msg.get("retry_after_s"))


__all__ = ["PriceClient", "ServeError"]
