"""Client for the pricing daemon.

    from repro.api import gpu_request
    from repro.serve.client import PriceClient

    with PriceClient("/tmp/repro-serve.sock") as c:
        result = c.price(gpu_request(spec, "A100", top_k=5))
        print(result.report.comparison_table())

``price_many`` pipelines a batch over one connection and yields results to
``on_result`` as the daemon streams them back (completion order), while the
returned list preserves request order.
"""
from __future__ import annotations

import json
import socket
import threading

from repro.api import PriceRequest, PriceResult

from .schema import SCHEMA_VERSION, decode, encode


class ServeError(RuntimeError):
    """An error line from the daemon (bad request, engine failure, skew)."""


class PriceClient:
    def __init__(self, socket_path: str, *, timeout: float | None = None):
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        if timeout is not None:
            self._sock.settimeout(timeout)
        self._sock.connect(socket_path)
        self._rfile = self._sock.makefile("rb")
        self._send_lock = threading.Lock()
        self._next_id = 0

    # ---- wire plumbing -------------------------------------------------
    def _send(self, payload: dict) -> None:
        payload.setdefault("schema_version", SCHEMA_VERSION)
        data = (json.dumps(payload, separators=(",", ":")) + "\n").encode()
        with self._send_lock:
            self._sock.sendall(data)

    def _recv(self) -> dict:
        line = self._rfile.readline()
        if not line:
            raise ServeError("daemon closed the connection")
        return json.loads(line)

    def _take_id(self) -> int:
        with self._send_lock:
            self._next_id += 1
            return self._next_id

    # ---- ops -----------------------------------------------------------
    def ping(self) -> bool:
        self._send({"op": "ping"})
        return self._recv().get("op") == "pong"

    def stats(self) -> dict:
        self._send({"op": "stats"})
        msg = self._recv()
        if not msg.get("ok"):
            raise ServeError(msg.get("error", "stats failed"))
        return msg["stats"]

    def shutdown_server(self) -> None:
        self._send({"op": "shutdown"})
        try:
            self._recv()
        except ServeError:
            pass

    def price(self, request: PriceRequest) -> PriceResult:
        """Price one request, blocking until its result streams back."""
        return self.price_many([request])[0]

    def price_many(self, requests, on_result=None) -> list:
        """Pipeline a batch; returns results in request order.

        ``on_result(index, result)`` fires in the daemon's completion
        order — a warm (memoized) answer arrives without waiting for cold
        sweeps submitted before it.
        """
        requests = list(requests)
        ids = {}
        for i, request in enumerate(requests):
            rid = self._take_id()
            ids[rid] = i
            self._send({"op": "price", "id": rid,
                        "request": encode(request)})
        out: list = [None] * len(requests)
        remaining = len(requests)
        first_error = None
        while remaining:
            msg = self._recv()
            rid = msg.get("id")
            if rid not in ids:
                continue            # e.g. an interleaved pong
            i = ids.pop(rid)
            remaining -= 1
            if not msg.get("ok"):
                first_error = first_error or ServeError(
                    msg.get("error", "pricing failed"))
                continue
            result = decode(msg["result"])
            out[i] = result
            if on_result is not None:
                on_result(i, result)
        if first_error is not None:
            raise first_error
        return out

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


__all__ = ["PriceClient", "ServeError"]
