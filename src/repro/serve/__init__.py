"""Pricing-as-a-service: daemon, scheduler, client, wire schema.

One long-lived process (``python -m repro.serve``) holds the warm
``InvariantCache`` and memoized stream tables; every code-generation run on
the machine prices against it through ``repro.serve.client.PriceClient``
using the same ``PriceRequest``/``PriceResult`` schema as the in-process
``repro.api.price``.  DESIGN.md §12 documents the architecture, wire
protocol, and the cache versioning/eviction contract.
"""
from .client import PriceClient, ServeError
from .daemon import PricingDaemon, serve
from .scheduler import DeadlineExceeded, QueueFullError, Scheduler
from .schema import SCHEMA_VERSION, request_digest

__all__ = ["PriceClient", "ServeError", "PricingDaemon", "serve",
           "Scheduler", "QueueFullError", "DeadlineExceeded",
           "SCHEMA_VERSION", "request_digest"]
