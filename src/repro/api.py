"""The unified pricing API: one request schema, one result schema.

Every way of asking the estimator a question — a GPU ``KernelSpec`` with
launch configs, ``(config, PallasKernelSpec)`` candidates, engine
``Workload``s, suite ``ModelPlan``s / ``PlanRef``s, traced Pallas kernels —
is a ``PriceRequest``; every answer is a ``PriceResult``.  The same frozen
dataclasses travel in-process (``price(request)``) and over the
``repro.serve`` wire (encoded by ``repro.serve.schema``), so a client of the
daemon and a caller of the library see identical results by construction.

    from repro.api import gpu_request, price

    result = price(gpu_request(spec, "A100", top_k=5))
    for e in result.ranking():
        print(e.config, e.perf, e.limiter)

Legacy entry points (``Explorer.rank_gpu`` / ``rank_pallas`` / ``explore`` /
``explore_plans``, ``suite.price_plans``, ``frontend.price_kernel``) survive
as deprecation shims over the same implementation — see the migration table
in README.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

from repro.core.engine import Explorer, Workload
from repro.core.machines import get_machine

API_VERSION = 1


@dataclass(frozen=True)
class PlanRef:
    """A wire-serializable reference to a suite model plan.

    ``ModelPlan`` holds an ``ArchConfig`` and interned spec callables —
    in-process only — so requests that cross a socket carry the recipe
    instead: ``price`` resolves it through ``configs.get_config`` +
    ``suite.lower_model`` on the serving side.
    """

    arch: str
    shape: str = "train_4k"
    batch: int = 1

    def resolve(self):
        from repro.configs import get_config
        from repro.suite import lower_model

        return lower_model(get_config(self.arch), self.shape, self.batch)


@dataclass(frozen=True)
class PriceRequest:
    """One pricing question, versioned and value-like.

    ``workloads``: engine ``Workload``s (a bare GPU ``KernelSpec`` is
    promoted, as ``Explorer`` always did).  ``plans``: ``{name: ModelPlan |
    PlanRef}`` (or an items tuple) — priced through suite lowering into the
    same sweep, results folded into ``result.suite``.  ``traced``:
    ``frontend.TracedSpecPayload``s from ``trace_payload``.  ``machines``:
    registry names (see ``core.machines.MACHINES``) or machine objects.
    ``gpu_configs`` overrides the GPU launch-config list for plan lowering
    and for workloads that do not carry their own.
    """

    workloads: tuple = ()
    plans: tuple = ()
    traced: tuple = ()
    machines: tuple = ()
    gpu_configs: tuple | None = None
    top_k: int | None = None
    strict: bool = False
    machine_axis: bool = False
    version: int = API_VERSION

    def __post_init__(self):
        object.__setattr__(self, "workloads", tuple(self.workloads))
        plans = self.plans
        if isinstance(plans, dict):
            plans = tuple(plans.items())
        object.__setattr__(self, "plans", tuple(tuple(p) for p in plans))
        object.__setattr__(self, "traced", tuple(self.traced))
        machines = self.machines
        if not isinstance(machines, (list, tuple)):
            machines = (machines,)
        object.__setattr__(self, "machines", tuple(machines))
        if self.gpu_configs is not None:
            object.__setattr__(self, "gpu_configs", tuple(self.gpu_configs))

    @property
    def empty(self) -> bool:
        return not (self.workloads or self.plans or self.traced)


@dataclass(frozen=True)
class PriceResult:
    """One pricing answer: the engine's ``ExplorationReport`` plus, when the
    request carried suite plans, the folded ``SuiteReport``.

    The common report accessors are re-exported so most callers never reach
    inside: ``result.ranking(workload, machine)``, ``result.best(...)``,
    ``result.cache_stats`` ...

    ``degraded=True`` marks a graceful-degradation answer (``price_bounds``,
    or a ``repro.serve`` deadline fallback): the ranking orders configs by
    their sound closed-form lower bound, not the exact model — callers that
    need the exact ranking must re-ask without a deadline.
    """

    report: Any
    suite: Any = None
    version: int = API_VERSION
    degraded: bool = False

    # ---- report passthrough --------------------------------------------
    @property
    def entries(self):
        return self.report.entries

    @property
    def skipped(self):
        return self.report.skipped

    @property
    def pruned(self):
        return self.report.pruned

    @property
    def cache_stats(self) -> dict:
        return self.report.cache_stats

    @property
    def wall_time_s(self) -> float:
        return self.report.wall_time_s

    def ranking(self, workload=None, machine=None):
        return self.report.ranking(workload, machine)

    def best(self, workload=None, machine=None):
        return self.report.best(workload, machine)

    def to_json_dict(self) -> dict:
        """The versioned, exact wire form (repro.serve.schema codec)."""
        from repro.serve.schema import encode

        return encode(self)


# ==========================================================================
# request builders — one per legacy entry-point shape
# ==========================================================================
def gpu_request(spec, machine, configs=None, *, capacity=None,
                total_threads: int = 1024, top_k: int | None = None,
                strict: bool = False) -> PriceRequest:
    """What ``Explorer.rank_gpu(spec, machine, configs)`` asked."""
    if configs is None:
        from repro.core.selector import enumerate_gpu_configs

        configs = enumerate_gpu_configs(total_threads)
    return PriceRequest(
        workloads=(Workload(name=spec.name, gpu_spec=spec,
                            gpu_configs=tuple(configs), capacity=capacity),),
        machines=(machine,), top_k=top_k, strict=strict,
    )


def pallas_request(candidates, machine="TPUv5e", *,
                   workload: str | None = None,
                   top_k: int | None = None,
                   strict: bool = False) -> PriceRequest:
    """What ``Explorer.rank_pallas(candidates, machine)`` asked."""
    candidates = tuple(candidates)
    name = workload or (candidates[0][1].name if candidates else "pallas")
    return PriceRequest(
        workloads=(Workload(name=name, tpu_candidates=candidates),),
        machines=(machine,), top_k=top_k, strict=strict,
    )


def plan_request(plans: dict, machines, *, gpu_configs=None,
                 top_k: int | None = None,
                 strict: bool = False) -> PriceRequest:
    """What ``suite.price_plans(plans, machines)`` asked.

    ``plans`` values may be ``ModelPlan``s (in-process) or ``PlanRef``s
    (serializable — resolved on the pricing side).
    """
    return PriceRequest(plans=plans, machines=machines,
                        gpu_configs=gpu_configs, top_k=top_k, strict=strict)


def kernel_request(call_fn, args, machines, *, name: str = "kernel",
                   costs=None, rename: dict | None = None,
                   top_k: int | None = None) -> PriceRequest:
    """What ``frontend.price_kernel(call_fn, args, machines)`` asked.

    Tracing happens here, eagerly (it needs jax and the kernel callable);
    the returned request carries only the pure-value payload, so it can
    cross the ``repro.serve`` wire.
    """
    from repro.frontend import trace_payload

    payload = trace_payload(call_fn, args, name=name, costs=costs,
                            rename=rename)
    return PriceRequest(traced=(payload,), machines=machines, top_k=top_k)


# ==========================================================================
# the one entry point
# ==========================================================================
def _resolve_machine(m):
    return get_machine(m) if isinstance(m, str) else m


def _resolve_plan(plan):
    return plan.resolve() if isinstance(plan, PlanRef) else plan


def _check_version(request: PriceRequest) -> None:
    if request.version > API_VERSION:
        raise ValueError(
            f"request version {request.version} is newer than this "
            f"library's API_VERSION {API_VERSION}")


def _request_workloads(request: PriceRequest):
    """Lower a request to its engine workload list (shared by ``price`` and
    ``price_bounds`` so both answer literally the same question)."""
    workloads = [
        w if isinstance(w, Workload) else Workload(name=w.name, gpu_spec=w)
        for w in request.workloads
    ]
    if request.gpu_configs is not None:
        workloads = [
            dataclasses.replace(w, gpu_configs=request.gpu_configs)
            if w.gpu_configs is None and w.gpu_spec is not None else w
            for w in workloads
        ]
    for t in request.traced:
        workloads.append(Workload(
            name=t.name, gpu_spec=t.gpu_spec,
            tpu_candidates=[({}, t.tpu_spec)]))

    plans = {name: _resolve_plan(p) for name, p in request.plans}
    if plans:
        from repro.suite import suite_gpu_configs

        gpu_configs = (list(request.gpu_configs)
                       if request.gpu_configs is not None
                       else suite_gpu_configs())
        for name, plan in plans.items():
            for w in plan.engine_workloads(gpu_configs):
                workloads.append(
                    dataclasses.replace(w, name=f"{name}::{w.name}"))
    return workloads, plans


def price(request: PriceRequest, *, engine: Explorer | None = None,
          progress=None) -> PriceResult:
    """Answer one ``PriceRequest`` in a single engine sweep.

    Workloads, traced kernels, and every suite plan's lowered kernels run
    through ONE ``Explorer`` sweep — sharing the invariant cache, cell-level
    dedupe, and (with ``machine_axis``) geometry batching — then suite plans
    fold their namespaced entries into ``result.suite``.  ``engine`` lets a
    long-lived caller (the ``repro.serve`` daemon, a warm notebook) reuse
    one Explorer across requests.
    """
    _check_version(request)
    explorer = engine or Explorer()
    machines = [_resolve_machine(m) for m in request.machines]
    workloads, plans = _request_workloads(request)

    report = explorer._explore(workloads, machines, strict=request.strict,
                               top_k=request.top_k, progress=progress,
                               machine_axis=request.machine_axis)
    if plans:
        from repro.suite import suite_from_report

        suite = suite_from_report(plans, machines, report)
    else:
        suite = None
    return PriceResult(report=report, suite=suite)


def price_bounds(request: PriceRequest, *,
                 engine: Explorer | None = None) -> PriceResult:
    """Answer a request with the tier-1 closed-form bound ranking only.

    This is the graceful-degradation path (DESIGN.md §13): it evaluates
    each backend's cheap bound tasks — no grid walks, no wave model, no
    worker pool — and ranks configurations by their sound lower bound on
    primary time.  Orders of magnitude cheaper than ``price`` and safe to
    serve when a deadline would otherwise be blown.  The result is flagged
    ``degraded=True``: the order is a bound ranking, not the exact one, and
    suite folding is skipped (no exact estimates exist to fold).
    """
    _check_version(request)
    explorer = engine or Explorer()
    machines = [_resolve_machine(m) for m in request.machines]
    workloads, _ = _request_workloads(request)
    report = explorer.bound_rank(workloads, machines, top_k=request.top_k)
    return PriceResult(report=report, degraded=True)


__all__ = [
    "API_VERSION", "PlanRef", "PriceRequest", "PriceResult",
    "gpu_request", "pallas_request", "plan_request", "kernel_request",
    "price", "price_bounds",
]
