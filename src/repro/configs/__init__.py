"""Assigned-architecture configs (``--arch <id>``)."""
from .base import SHAPES, ArchConfig, ShapeSpec, valid_cells

ARCHS = {
    "rwkv6-1.6b": "rwkv6_1b6",
    "qwen1.5-32b": "qwen15_32b",
    "phi3-mini-3.8b": "phi3_mini",
    "qwen1.5-110b": "qwen15_110b",
    "granite-3-2b": "granite3_2b",
    "whisper-base": "whisper_base",
    "zamba2-2.7b": "zamba2_2b7",
    "internvl2-76b": "internvl2_76b",
    "mixtral-8x7b": "mixtral_8x7b",
    "arctic-480b": "arctic_480b",
}


def get_config(arch: str) -> ArchConfig:
    import importlib

    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG
