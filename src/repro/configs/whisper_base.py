"""whisper-base — enc-dec audio; conv frontend stubbed to precomputed frame
embeddings (input_specs) [arXiv:2212.04356].  6 encoder + 6 decoder layers."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512, n_heads=8,
    n_kv=8, d_ff=2048, vocab=51865, enc_layers=6, frontend="audio",
    frontend_dim=512, frontend_tokens=1500, norm="layernorm", mlp="gelu",
    rope_theta=10000.0,
)
