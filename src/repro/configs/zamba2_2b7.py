"""zamba2-2.7b — hybrid Mamba2 + weight-shared attention blocks
[arXiv:2411.15242].  54 mamba layers, shared attn+MLP every 6."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560, n_heads=32,
    n_kv=32, d_ff=10240, vocab=32000, block_pattern="mamba_hybrid",
    hybrid_attn_every=6, ssm_state=64, ssm_head_dim=64,
    swa_window=4096,  # shared-attn block uses SWA at long context (DESIGN §4)
)
