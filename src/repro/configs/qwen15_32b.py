"""qwen1.5-32b — dense GQA kv=40, QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120, n_heads=40,
    n_kv=40, d_ff=27392, vocab=152064, qkv_bias=True,
)
