"""rwkv6-1.6b — Finch: attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048, n_heads=32,
    n_kv=32, d_ff=7168, vocab=65536, block_pattern="rwkv", ssm_head_dim=64,
    norm="layernorm", mlp="swiglu",
)
