"""internvl2-76b — VLM: InternViT frontend (stub patch embeddings) +
InternLM2-style dense backbone [arXiv:2404.16821]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192, n_heads=64,
    n_kv=8, d_ff=28672, vocab=128256, frontend="vision", frontend_dim=1024,
    frontend_tokens=1024,
)
