"""arctic-480b — MoE 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168, n_heads=56,
    n_kv=8, d_ff=4864, vocab=32000, n_experts=128, top_k=2, dense_residual=True,
)
