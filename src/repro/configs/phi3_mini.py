"""phi3-mini-3.8b — dense, RoPE SwiGLU GQA kv=32 [arXiv:2404.14219]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072, n_heads=32,
    n_kv=32, d_ff=8192, vocab=32064,
)
