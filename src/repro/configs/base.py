"""Architecture config schema + assigned input-shape sets.

One ``ArchConfig`` instance per assigned architecture lives in
``repro.configs.<id>``; ``SHAPES`` defines the four assigned input shapes.
``reduced()`` derives the smoke-test config (same family, tiny dims).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    swa_window: int = 0            # 0 -> full attention
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 2
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    # SSM / hybrid
    block_pattern: str = "attn"    # attn | rwkv | mamba_hybrid
    hybrid_attn_every: int = 6
    ssm_state: int = 64
    ssm_head_dim: int = 64
    # encoder-decoder / frontends
    enc_layers: int = 0            # >0 -> encoder-decoder (whisper)
    frontend: str = ""             # "" | audio | vision
    frontend_dim: int = 1024
    frontend_tokens: int = 1500    # frames (audio) / patches (vision)
    # numerics
    param_dtype: str = "bfloat16"
    remat: bool = True
    kv_int8: bool = False          # int8 KV cache (serving capacity knob)
    seq_parallel: bool = False     # Megatron-SP residual stream (per-arch)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 (Megatron-style) so the vocab axis shards
        cleanly on the 16-way model axis (granite/whisper have odd vocabs)."""
        return -(-self.vocab // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch lower long_500k? (SSM / hybrid / sliding-window)."""
        return self.block_pattern in ("rwkv", "mamba_hybrid") or self.swa_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same family/topology, tiny dims."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv=max(1, min(self.n_kv, 2)) if self.n_kv < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            frontend_dim=64 if self.frontend else self.frontend_dim,
            frontend_tokens=16 if self.frontend else self.frontend_tokens,
            hybrid_attn_every=2 if self.block_pattern == "mamba_hybrid" else self.hybrid_attn_every,
            ssm_state=16,
            ssm_head_dim=32,
            remat=False,
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long_decode"),
}


def valid_cells(cfg: ArchConfig):
    """The assigned (arch x shape) cells, honoring the long-context rule."""
    out = []
    for s in SHAPES.values():
        if s.kind == "long_decode" and not cfg.is_sub_quadratic:
            continue  # skip noted in DESIGN.md §4
        out.append(s)
    return out
