"""Deterministic synthetic token pipeline with host sharding + prefetch.

Production layout: each host generates only its local shard of the global
batch (seeded by (step, host)); ``ShardedBatchIterator`` yields
device-put-able numpy arrays plus the GlobalDeviceArray-style callback used
by the launcher to assemble jax.Arrays on a mesh.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    frontend_dim: int = 0


def batch_for_step(cfg: DataConfig, step: int, host: int = 0, n_hosts: int = 1):
    """Deterministic batch shard for (step, host): tokens + labels (+frontend)."""
    if cfg.global_batch % n_hosts:
        raise ValueError("global batch must divide across hosts")
    local = cfg.global_batch // n_hosts
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, host]))
    tokens = rng.integers(0, cfg.vocab, (local, cfg.seq_len), dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    out = {"tokens": tokens, "labels": labels}
    if cfg.frontend_tokens:
        out["frontend"] = rng.standard_normal(
            (local, cfg.frontend_tokens, cfg.frontend_dim), dtype=np.float32
        )
    return out


class ShardedBatchIterator:
    """Background-thread prefetching iterator over deterministic batches."""

    def __init__(self, cfg: DataConfig, host: int = 0, n_hosts: int = 1,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.host = host
        self.n_hosts = n_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = batch_for_step(self.cfg, step, self.host, self.n_hosts)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
