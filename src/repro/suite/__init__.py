"""Workload suite: model configs lowered into estimator-priced kernel plans.

The paper's closing claim — the estimator integrates with *any* code
generator that can produce address expressions — applied to the model-config
zoo: ``lower_model`` decomposes a ``repro.configs`` architecture into a
``ModelPlan`` of kernel workloads (attention cores, projection/MoE/SSM
GEMMs), and whole batches of plans are priced across GPU and TPU machines
in one exploration-engine sweep through ``repro.api``.  See DESIGN.md §8
for the lowering contract.

    from repro.api import PlanRef, plan_request, price

    suite = price(plan_request({"mixtral-8x7b": PlanRef("mixtral-8x7b")},
                               ["V100", "A100", "TPUv5e"])).suite
    print(suite.table())
"""
from .lowering import (
    SUITE_GPU_BLOCKS,
    KernelWorkload,
    ModelPlan,
    lower_all,
    lower_model,
    pad_tile,
    suite_gpu_configs,
)
from .report import (
    ModelReport,
    SuiteReport,
    WorkloadPricing,
    machine_kind,
    price_plans,
    suite_from_report,
)

__all__ = [
    "KernelWorkload", "ModelPlan", "lower_model", "lower_all",
    "pad_tile", "suite_gpu_configs", "SUITE_GPU_BLOCKS",
    "ModelReport", "SuiteReport", "WorkloadPricing",
    "machine_kind", "price_plans", "suite_from_report",
]
