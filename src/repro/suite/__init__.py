"""Workload suite: model configs lowered into estimator-priced kernel plans.

The paper's closing claim — the estimator integrates with *any* code
generator that can produce address expressions — applied to the model-config
zoo: ``lower_model`` decomposes a ``repro.configs`` architecture into a
``ModelPlan`` of kernel workloads (attention cores, projection/MoE/SSM
GEMMs), and ``price_plans`` prices whole batches of plans across GPU and TPU
machines in one exploration-engine sweep.  See DESIGN.md §8 for the lowering
contract.

    from repro.configs import get_config
    from repro.suite import lower_model, price_plans
    from repro.core.machines import A100, TPU_V5E, V100

    plan = lower_model(get_config("mixtral-8x7b"), "train_4k")
    suite = price_plans({"mixtral-8x7b": plan}, [V100, A100, TPU_V5E])
    print(suite.table())
"""
from .lowering import (
    SUITE_GPU_BLOCKS,
    KernelWorkload,
    ModelPlan,
    lower_all,
    lower_model,
    pad_tile,
    suite_gpu_configs,
)
from .report import (
    ModelReport,
    SuiteReport,
    WorkloadPricing,
    machine_kind,
    price_plans,
)

__all__ = [
    "KernelWorkload", "ModelPlan", "lower_model", "lower_all",
    "pad_tile", "suite_gpu_configs", "SUITE_GPU_BLOCKS",
    "ModelReport", "SuiteReport", "WorkloadPricing",
    "machine_kind", "price_plans",
]
