"""Plan pricing and per-model aggregation (DESIGN.md §8).

``price_plans`` lowers a batch of ``ModelPlan``s through ONE
``Explorer.explore_plans`` sweep — every model, machine, and candidate
configuration shares the engine's invariant cache — and folds the per-cell
rankings into ``ModelReport``s: per-workload best config, count-weighted
predicted time, HBM/DRAM traffic, roofline placement (``core.roofline``),
and a ranked machine comparison per model.

GPU cells are priced by the paper's CUDA-core model (``matmul_naive``
address expressions at the machine's measured FP64 rate); TPU cells by the
Pallas analytical model.  Within a machine type the comparison is exact;
across types it compares the two analytical models' predictions.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field as dc_field

from repro.core.engine import Explorer
from repro.core.machines import TPUMachine
from repro.core.roofline import RooflineReport, report_from_values

from .lowering import suite_gpu_configs


def machine_kind(machine) -> str:
    return "tpu" if isinstance(machine, TPUMachine) else "gpu"


@dataclass
class WorkloadPricing:
    """Best configuration of one kernel workload on one machine."""

    name: str
    role: str
    count: int
    config: object            # winning config (dict or LaunchConfig)
    time_s: float             # one instance
    limiter: str
    hbm_bytes: float          # one instance
    flops: float              # one instance (useful flops)

    @property
    def total_time_s(self) -> float:
        return self.time_s * self.count


@dataclass
class ModelReport:
    """Aggregate pricing of one (model, shape) plan on one machine."""

    model: str
    shape: str
    machine: str
    rows: list = dc_field(default_factory=list)   # list[WorkloadPricing]
    missing: list = dc_field(default_factory=list)  # workloads w/o feasible cfg
    n_skipped: int = 0
    roofline: RooflineReport | None = None

    @property
    def complete(self) -> bool:
        return not self.missing

    @property
    def time_s(self) -> float:
        return sum(r.total_time_s for r in self.rows)

    @property
    def flops(self) -> float:
        return sum(r.flops * r.count for r in self.rows)

    @property
    def hbm_bytes(self) -> float:
        return sum(r.hbm_bytes * r.count for r in self.rows)

    @property
    def roofline_fraction(self) -> float:
        """Roofline bound over predicted time: how close the kernel-level
        plan comes to the machine's aggregate compute/memory ceiling."""
        if self.roofline is None or self.time_s <= 0:
            return 0.0
        return self.roofline.t_bound / self.time_s

    def limiter_counts(self) -> dict:
        out: dict = {}
        for r in self.rows:
            out[r.limiter] = out.get(r.limiter, 0) + 1
        return out

    def by_role(self) -> dict:
        """role -> summed predicted time (the per-layer cost breakdown)."""
        out: dict = {}
        for r in self.rows:
            out[r.role] = out.get(r.role, 0.0) + r.total_time_s
        return out

    def to_row(self) -> dict:
        # normalized summary row: raw SI units throughout ("flops",
        # "hbm_bytes"), same field names the engine's EvalResult/roofline
        # vocabulary uses — unit scaling belongs to presentation layers
        rf = self.roofline
        return {
            "model": self.model,
            "shape": self.shape,
            "machine": self.machine,
            "time_s": self.time_s,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "dominant": rf.dominant if rf else "n/a",
            "roofline_fraction": self.roofline_fraction,
            "limiters": self.limiter_counts(),
            "complete": self.complete,
            "missing": list(self.missing),
            "n_workloads": len(self.rows),
            "n_skipped": self.n_skipped,
        }


@dataclass
class SuiteReport:
    """Every (model, machine) ModelReport of one suite sweep."""

    reports: dict = dc_field(default_factory=dict)  # (model, machine) -> MR
    cache_stats: dict = dc_field(default_factory=dict)
    wall_time_s: float = 0.0

    def get(self, model: str, machine: str) -> ModelReport | None:
        return self.reports.get((model, machine))

    def models(self) -> list:
        seen: dict = {}
        for (model, _), _r in self.reports.items():
            seen.setdefault(model, None)
        return list(seen)

    def machine_ranking(self, model: str) -> list:
        """[(machine, predicted time)] fastest first for one model."""
        rows = [
            (machine, r.time_s)
            for (m, machine), r in self.reports.items()
            if m == model and r.rows
        ]
        return sorted(rows, key=lambda t: t[1])

    def table(self) -> str:
        rows = [("model", "machine", "time/pass", "TFLOP", "HBM GB",
                 "dominant", "roofl%", "workloads")]
        for model in self.models():
            for machine, t in self.machine_ranking(model):
                r = self.reports[(model, machine)]
                rows.append((
                    model, machine, f"{t*1e3:.2f}ms",
                    f"{r.flops/1e12:.2f}", f"{r.hbm_bytes/1e9:.2f}",
                    r.roofline.dominant if r.roofline else "n/a",
                    f"{100*r.roofline_fraction:.0f}%",
                    f"{len(r.rows)}" + (f" (+{len(r.missing)} missing)"
                                        if r.missing else ""),
                ))
        widths = [max(len(str(row[i])) for row in rows)
                  for i in range(len(rows[0]))]
        lines = ["  ".join(str(c).ljust(w) for c, w in zip(row, widths)).rstrip()
                 for row in rows]
        lines.insert(1, "-" * len(lines[0]))
        return "\n".join(lines)

    def to_json(self) -> dict:
        """Versioned summary view (the shape BENCH_model_suite.json carries);
        ``to_wire``/``from_wire`` give the exact round-trippable form."""
        from repro.serve.schema import SCHEMA_VERSION

        return {
            "schema": {"kind": "suite_report", "version": SCHEMA_VERSION},
            "cells": [r.to_row() for r in self.reports.values()],
            "ranking": {m: [(name, t) for name, t in self.machine_ranking(m)]
                        for m in self.models()},
            "cache_stats": dict(self.cache_stats),
            "wall_time_s": self.wall_time_s,
        }

    def to_wire(self) -> dict:
        """Exact, versioned JSON-safe form (repro.serve.schema codec)."""
        from repro.serve.schema import encode

        return encode(self)

    @classmethod
    def from_wire(cls, obj) -> "SuiteReport":
        from repro.serve.schema import decode

        out = decode(obj)
        if not isinstance(out, cls):
            raise TypeError(f"wire object decodes to {type(out).__name__}, "
                            f"not {cls.__name__}")
        return out


# ==========================================================================
def _roofline_for(name: str, machine, flops: float, hbm_bytes: float,
                  elem_bytes: int = 2) -> RooflineReport:
    """Aggregate roofline placement; GPU machines get the two-term version
    of ``core.roofline`` built from their measured peaks."""
    if isinstance(machine, TPUMachine):
        return report_from_values(
            name, flops=flops, hbm_bytes=hbm_bytes, coll_wire_bytes=0.0,
            n_chips=1, machine=machine, model_flops_total=flops,
            elem_bytes=elem_bytes,
        )
    t_compute = flops / machine.peak_flops_dp
    t_memory = hbm_bytes / machine.dram_bw
    return RooflineReport(
        name=name, flops=flops, hbm_bytes=hbm_bytes,
        coll_payload_bytes=0.0, coll_wire_bytes=0.0,
        t_compute=t_compute, t_memory=t_memory, t_collective=0.0,
        dominant="compute" if t_compute >= t_memory else "memory",
        model_flops=flops, useful_flops_ratio=1.0,
        detail={"t_model_compute": t_compute},
    )


def _price_row(wl, entry, kind) -> WorkloadPricing:
    est = entry.estimate
    if kind == "tpu":
        t = est.total_time
        hbm = est.hbm_bytes
    else:
        points = float(wl.params["M"]) * wl.params["K"] * wl.params["N"]
        t = points / est.perf_lups
        hbm = (est.dram_load_per_lup + est.dram_store_per_lup) * points
    return WorkloadPricing(
        name=wl.name, role=wl.role, count=wl.count, config=entry.config,
        time_s=t, limiter=entry.limiter, hbm_bytes=hbm, flops=wl.flops(),
    )


def suite_from_report(plans: dict, machines, report) -> SuiteReport:
    """Fold one engine ``ExplorationReport`` (plan workloads namespaced as
    ``"<model>::<workload>"``) into per-(model, machine) ``ModelReport``s.

    Shared by the in-process path (``_price_plans``) and ``repro.api.price``
    — a daemon sweep that mixed suite plans with other requests folds the
    same way, reading only its own namespaced entries.
    """
    suite = SuiteReport(cache_stats=dict(report.cache_stats),
                        wall_time_s=report.wall_time_s)
    # index entries/skips once: (workload name, machine) -> best entry
    best: dict = {}
    for e in report.entries:
        best.setdefault((e.workload, e.machine), e)  # entries are ranked
    n_skip: dict = {}
    for s in report.skipped:
        n_skip[(s.workload, s.machine)] = n_skip.get(
            (s.workload, s.machine), 0) + 1

    for name, plan in plans.items():
        for machine in machines:
            kind = machine_kind(machine)
            mr = ModelReport(model=name, shape=plan.shape.name,
                             machine=machine.name)
            for wl in plan.workloads:
                if kind not in wl.backends:
                    continue
                key = (f"{name}::{wl.name}", machine.name)
                mr.n_skipped += n_skip.get(key, 0)
                entry = best.get(key)
                if entry is None:
                    mr.missing.append(wl.name)
                    continue
                mr.rows.append(_price_row(wl, entry, kind))
            mr.roofline = _roofline_for(
                f"{name}/{plan.shape.name}/{machine.name}",
                machine, mr.flops, mr.hbm_bytes)
            suite.reports[(name, machine.name)] = mr
    return suite


def _price_plans(plans: dict, machines, *, explorer: Explorer | None = None,
                 gpu_configs=None, strict: bool = False,
                 top_k: int | None = None, progress=None) -> SuiteReport:
    """Price ``{name: ModelPlan}`` on every machine in one engine sweep.

    ``top_k`` switches the sweep to the engine's tiered bound-then-refine
    search (the suite only consumes each cell's best config, so ``top_k=1``
    yields identical reports while skipping most structural work on fresh
    caches); ``progress(done, total)`` observes per-config completion.
    Pass ``explorer=Explorer(parallel=True, cache_path=...)`` to persist the
    invariant cache across runs — a warm re-run of the whole suite then
    skips essentially all structural evaluation.
    """
    t0 = time.perf_counter()
    explorer = explorer or Explorer(parallel=True)
    gpu_configs = gpu_configs or suite_gpu_configs()
    engine_plans = {
        name: plan.engine_workloads(gpu_configs)
        for name, plan in plans.items()
    }
    report = explorer._explore_plans(engine_plans, machines, strict=strict,
                                     top_k=top_k, progress=progress)
    suite = suite_from_report(plans, machines, report)
    # wall time covers lowering + folding, not just the engine sweep
    suite.wall_time_s = time.perf_counter() - t0
    return suite


def price_plans(plans: dict, machines, *, explorer: Explorer | None = None,
                gpu_configs=None, strict: bool = False,
                top_k: int | None = None, progress=None) -> SuiteReport:
    """Deprecated: use ``repro.api.price(plan_request(...))``."""
    warnings.warn(
        "price_plans() is deprecated; use repro.api.price("
        "repro.api.plan_request(...)) instead",
        DeprecationWarning, stacklevel=2,
    )
    return _price_plans(plans, machines, explorer=explorer,
                        gpu_configs=gpu_configs, strict=strict, top_k=top_k,
                        progress=progress)
