"""Model-config -> kernel-plan lowering (DESIGN.md §8).

Walks the forward pass of any ``repro.configs`` architecture — mirroring the
layer stack ``repro.models.lm.forward`` actually executes — and decomposes it
into a ``ModelPlan`` of per-layer kernel workloads the exploration engine can
price:

  * attention cores  -> ``kernels.flash_attention.candidate_specs`` (TPU) and
    per-head GEMM equivalents as address expressions (GPU);
  * every projection / MLP / MoE / LM-head matmul -> ``kernels.matmul``
    candidates (TPU) and ``core.specs.matmul_naive`` (GPU), with MoE expert
    FFNs weighted by the routing fan-out (``top_k``/``n_experts``);
  * SSM / RWKV mixers -> the GEMM equivalents of their chunked-parallel scan
    forms (chunk sizes shared with ``layers.ssm`` via ``layers.shapes``).

The plan is deliberately *per layer*: layers that share shapes produce
structurally identical workloads, and the engine's invariant cache collapses
them — re-pricing a 60-layer model costs a handful of distinct structural
tasks (pinned by ``tests/test_suite.py``).

Deliberately not lowered (negligible or non-matmul work, see DESIGN.md §8):
embedding gathers, norms, RoPE, residual adds, RWKV's rank-64 decay lora,
Mamba's depthwise k=4 conv, and the stubbed audio/vision frontends (their
projection into ``d_model`` *is* lowered).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field as dc_field

from repro import obs
from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.core.access import LaunchConfig
from repro.layers import shapes as lshapes

TILE = 128  # MXU/lane tile: TPU block candidates need tile-divisible extents


class UnsupportedShape(ValueError):
    """The (arch, shape) cell is excluded by design (``valid_cells``), as
    opposed to a malformed config, which raises plain ``ValueError``."""


def pad_tile(x: int) -> int:
    """Round up to the 128 tile (minimum one tile) — what padding the
    compiler would apply to make the shape tileable."""
    return max(TILE, -(-int(x) // TILE) * TILE)


# GPU launch configurations the suite prices per matmul workload: a small
# representative set of (x=n, y=m, z=k) thread-block shapes (1024-thread
# tiles of the paper's eq.-6 grid plus two small blocks for skinny GEMMs).
SUITE_GPU_BLOCKS = [
    (32, 8, 4), (16, 16, 4), (64, 16, 1), (128, 8, 1), (32, 32, 1),
    (16, 8, 8), (32, 4, 1), (16, 8, 2),
]


def suite_gpu_configs() -> list[LaunchConfig]:
    return [LaunchConfig(block=b) for b in SUITE_GPU_BLOCKS]


# interned generator outputs per shape class (see KernelWorkload); the suite
# prices thousands of per-layer workloads drawn from a few dozen shapes
_candidate_memo: dict = {}


@dataclass
class KernelWorkload:
    """One kernel invocation class inside a model's forward pass.

    ``kind`` selects the generator (``matmul`` | ``flash_attention``);
    ``backends`` says which machine types this workload is *for* (attention
    cores lower differently per backend, everything else is both);
    ``count`` is the multiplicity within its layer (expert fan-out, per-head
    GEMMs, scan chunks); ``params`` are the logical, unpadded shapes.
    """

    name: str                 # unique within the plan, e.g. "L03.attn.qkv"
    kind: str                 # "matmul" | "flash_attention"
    role: str                 # e.g. "attn.qkv", "moe.expert_in"
    params: dict
    count: int = 1
    backends: tuple = ("gpu", "tpu")

    # ---- generator coupling -------------------------------------------
    def tpu_candidates(self) -> list | None:
        """(config, PallasKernelSpec) candidates — shapes tile-padded.

        Interned per shape class: repeated layers (and repeated models)
        return the *same* candidate objects, so downstream consumers — the
        engine's cell-level dedupe, memoized spec hashes, cache probes —
        compare by identity instead of re-walking equal spec trees.
        """
        if "tpu" not in self.backends:
            return None
        key = (self.kind, tuple(sorted(self.params.items())))
        cands = _candidate_memo.get(key)
        if cands is not None:
            return cands
        from repro.kernels import get_generator

        p = self.params
        if self.kind == "matmul":
            gen = get_generator("matmul")
            cands = list(gen(pad_tile(p["M"]), pad_tile(p["K"]),
                             pad_tile(p["N"]), elem_bytes=p["elem_bytes"]))
        elif self.kind == "flash_attention":
            gen = get_generator("flash_attention")
            cands = list(gen(p["B"], p["Hq"], p["Hkv"], p["Sq"], p["Skv"],
                             p["D"], causal=p["causal"],
                             elem_bytes=p["elem_bytes"]))
        else:
            raise ValueError(f"no TPU generator for kind {self.kind!r}")
        _candidate_memo[key] = cands
        return cands

    def gpu_spec(self):
        """Address-expression artifact for the GPU estimator (exact shapes —
        the GPU model does not require tile divisibility).  Interned like
        ``tpu_candidates``."""
        if "gpu" not in self.backends:
            return None
        if self.kind != "matmul":
            return None  # attention cores lower to GEMM workloads for GPU
        p = self.params
        key = ("gpu", self.kind, tuple(sorted(self.params.items())))
        spec = _candidate_memo.get(key)
        if spec is None:
            from repro.core.specs import matmul_naive

            spec = matmul_naive(p["M"], p["K"], p["N"],
                                elem_bytes=p["elem_bytes"])
            _candidate_memo[key] = spec
        return spec

    # ---- accounting ----------------------------------------------------
    def flops(self) -> float:
        """Useful flops of ONE instance (multiply by ``count`` for the
        layer total)."""
        p = self.params
        if self.kind == "matmul":
            return 2.0 * p["M"] * p["K"] * p["N"]
        tri = 0.5 if p["causal"] and p["Sq"] == p["Skv"] else 1.0
        return 4.0 * p["B"] * p["Hq"] * p["Sq"] * p["Skv"] * p["D"] * tri

    def structural_key(self) -> tuple:
        """Workloads sharing this key share every structural task."""
        return (self.kind, self.backends,
                tuple(sorted(self.params.items())))


@dataclass
class ModelPlan:
    """The priceable decomposition of one (model config, input shape) cell."""

    config: ArchConfig
    shape: ShapeSpec
    batch: int
    workloads: list = dc_field(default_factory=list)

    # ---- structure -----------------------------------------------------
    def kind_counts(self) -> dict:
        out: dict = {}
        for w in self.workloads:
            out[w.kind] = out.get(w.kind, 0) + 1
        return out

    def role_counts(self) -> dict:
        """role -> (number of workload instances, sum of their counts)."""
        out: dict = {}
        for w in self.workloads:
            n, c = out.get(w.role, (0, 0))
            out[w.role] = (n + 1, c + w.count)
        return out

    def distinct(self) -> list:
        """(representative workload, total count) per structural class —
        the work the engine actually evaluates after memoization."""
        seen: dict = {}
        order = []
        for w in self.workloads:
            k = w.structural_key()
            if k in seen:
                rep, c = seen[k]
                seen[k] = (rep, c + w.count)
            else:
                seen[k] = (w, w.count)
                order.append(k)
        return [seen[k] for k in order]

    def total_flops(self, backend: str = "tpu") -> float:
        """Useful flops of one forward pass under one backend's lowering
        (attention cores lower differently per backend, so summing every
        workload would double-count them)."""
        return sum(w.flops() * w.count for w in self.workloads
                   if backend in w.backends)

    # ---- engine coupling ----------------------------------------------
    def engine_workloads(self, gpu_configs=None) -> list:
        """Lower to ``engine.Workload``s (one per kernel workload)."""
        from repro.core.engine import Workload

        gpu_configs = gpu_configs or suite_gpu_configs()
        out = []
        # enumerate each structural class once: repeated layers share the
        # spec and candidate-list objects (the engine's cache dedupes
        # evaluation; this dedupes enumeration)
        by_class: dict = {}
        for w in self.workloads:
            k = w.structural_key()
            if k not in by_class:
                by_class[k] = (w.gpu_spec(), w.tpu_candidates())
            spec, cands = by_class[k]
            out.append(Workload(
                name=w.name,
                gpu_spec=spec,
                gpu_configs=gpu_configs if spec is not None else None,
                tpu_candidates=cands,
            ))
        return out


# ==========================================================================
# lowering
# ==========================================================================
def _mm(name, role, M, K, N, *, count=1, backends=("gpu", "tpu"),
        elem_bytes=2) -> KernelWorkload:
    return KernelWorkload(
        name=name, kind="matmul", role=role, count=count, backends=backends,
        params={"M": int(M), "K": int(K), "N": int(N),
                "elem_bytes": elem_bytes},
    )


def _attn_core(prefix, *, B, Hq, Hkv, Sq, Skv, D, causal, decode,
               elem_bytes=2) -> list:
    """Attention core: FA candidates on TPU, per-head GEMMs on GPU.

    Decode steps (Sq per sequence = 1) cannot tile a flash kernel's query
    axis, so both backends price the QK^T / AV GEMV-batch equivalents —
    M is the token batch, one GEMM class per query head.
    """
    if decode:
        return [
            _mm(f"{prefix}.core[qk]", "attn.core[qk]", B, D, Skv, count=Hq,
                elem_bytes=elem_bytes),
            _mm(f"{prefix}.core[av]", "attn.core[av]", B, Skv, D, count=Hq,
                elem_bytes=elem_bytes),
        ]
    fa = KernelWorkload(
        name=f"{prefix}.core[fa]", kind="flash_attention",
        role="attn.core[fa]", backends=("tpu",),
        params={"B": B, "Hq": Hq, "Hkv": Hkv, "Sq": Sq, "Skv": Skv, "D": D,
                "causal": causal, "elem_bytes": elem_bytes},
    )
    return [
        fa,
        _mm(f"{prefix}.core[qk]", "attn.core[qk]", Sq, D, Skv,
            count=B * Hq, backends=("gpu",), elem_bytes=elem_bytes),
        _mm(f"{prefix}.core[av]", "attn.core[av]", Sq, Skv, D,
            count=B * Hq, backends=("gpu",), elem_bytes=elem_bytes),
    ]


def _attn_block(prefix, cfg: ArchConfig, *, T, B, Sq, Skv, causal, decode,
                role_prefix="attn") -> list:
    """Self-attention sublayer: fused QKV projection, core, out projection."""
    hd = cfg.resolved_head_dim
    pr = lshapes.attention_proj_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv, hd)
    wls = [_mm(f"{prefix}.{role_prefix}.qkv", f"{role_prefix}.qkv",
               T, *pr["qkv"])]
    core = _attn_core(f"{prefix}.{role_prefix}", B=B, Hq=cfg.n_heads,
                      Hkv=cfg.n_kv, Sq=Sq, Skv=Skv, D=hd, causal=causal,
                      decode=decode)
    for w in core:
        w.role = w.role.replace("attn.", f"{role_prefix}.", 1)
    wls += core
    wls.append(_mm(f"{prefix}.{role_prefix}.out", f"{role_prefix}.out",
                   T, *pr["out"]))
    return wls


def _mlp_block(prefix, cfg: ArchConfig, T, *, role_prefix="mlp") -> list:
    sh = lshapes.mlp_shapes(cfg.d_model, cfg.d_ff, cfg.mlp)
    (in_shape, n_in), (out_shape, _) = sh["in"], sh["out"]
    return [
        _mm(f"{prefix}.{role_prefix}.in", f"{role_prefix}.in",
            T, *in_shape, count=n_in),
        _mm(f"{prefix}.{role_prefix}.out", f"{role_prefix}.out",
            T, *out_shape),
    ]


def _moe_block(prefix, cfg: ArchConfig, T) -> list:
    """MoE sublayer with the routing fan-out made explicit: every token is
    dispatched to ``top_k`` experts, so each of the ``n_experts`` expert
    FFNs processes ``T * top_k / n_experts`` tokens (balanced routing, the
    capacity model's design point)."""
    sh = lshapes.moe_shapes(cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.mlp)
    Te = max(1, math.ceil(T * cfg.top_k / cfg.n_experts))
    (r_shape, _) = sh["router"]
    (in_shape, n_in) = sh["expert_in"]
    (out_shape, _) = sh["expert_out"]
    wls = [
        _mm(f"{prefix}.moe.router", "moe.router", T, *r_shape),
        _mm(f"{prefix}.moe.expert_in", "moe.expert_in", Te, *in_shape,
            count=cfg.n_experts * n_in),
        _mm(f"{prefix}.moe.expert_out", "moe.expert_out", Te, *out_shape,
            count=cfg.n_experts),
    ]
    if cfg.dense_residual:  # arctic: dense MLP in parallel with the experts
        wls += _mlp_block(prefix, cfg, T)
    return wls


def _scan_equivalents(prefix, role_prefix, *, T, heads, head_dim, state,
                      chunk, decode) -> list:
    """GEMM equivalents of a chunked-parallel linear-attention/SSM scan.

    Per chunk and head (quadratic within the chunk, linear across chunks):
    ``intra``      (C x state x C)     intra-chunk interaction scores,
    ``intra_out``  (C x C x head_dim)  scores applied to values,
    ``state``      (state x C x head_dim) cross-chunk state update,
    ``state_out``  (C x state x head_dim) carried state applied to queries.
    Decode steps use the exact recurrence: a rank-1 state update plus a
    state readout per token per head.
    """
    if decode:
        return [
            _mm(f"{prefix}.{role_prefix}[state]", f"{role_prefix}[state]",
                state, 1, head_dim, count=heads * T),
            _mm(f"{prefix}.{role_prefix}[state_out]",
                f"{role_prefix}[state_out]",
                1, state, head_dim, count=heads * T),
        ]
    n = heads * max(1, math.ceil(T / chunk))
    C = chunk
    return [
        _mm(f"{prefix}.{role_prefix}[intra]", f"{role_prefix}[intra]",
            C, state, C, count=n),
        _mm(f"{prefix}.{role_prefix}[intra_out]", f"{role_prefix}[intra_out]",
            C, C, head_dim, count=n),
        _mm(f"{prefix}.{role_prefix}[state]", f"{role_prefix}[state]",
            state, C, head_dim, count=n),
        _mm(f"{prefix}.{role_prefix}[state_out]", f"{role_prefix}[state_out]",
            C, state, head_dim, count=n),
    ]


def _mamba_block(prefix, cfg: ArchConfig, T, decode) -> list:
    d = lshapes.mamba2_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim)
    return [
        _mm(f"{prefix}.ssm.in", "ssm.in", T, cfg.d_model, d["d_in_proj"]),
        *_scan_equivalents(prefix, "ssm.scan", T=T, heads=d["n_heads"],
                           head_dim=d["head_dim"], state=d["d_state"],
                           chunk=d["chunk"], decode=decode),
        _mm(f"{prefix}.ssm.out", "ssm.out", T, d["d_inner"], cfg.d_model),
    ]


def _rwkv_block(prefix, cfg: ArchConfig, T, decode) -> list:
    d = lshapes.rwkv6_dims(cfg.d_model, cfg.ssm_head_dim)
    ch = lshapes.rwkv6_channel_mix_shapes(cfg.d_model, cfg.d_ff)
    return [
        _mm(f"{prefix}.rwkv.proj", "rwkv.proj", T, cfg.d_model, cfg.d_model,
            count=d["n_proj"]),
        *_scan_equivalents(prefix, "rwkv.wkv", T=T, heads=d["n_heads"],
                           head_dim=d["head_dim"], state=d["head_dim"],
                           chunk=d["chunk"], decode=decode),
        _mm(f"{prefix}.rwkv.out", "rwkv.out", T, cfg.d_model, cfg.d_model),
        _mm(f"{prefix}.rwkv.chan[key]", "rwkv.chan[key]", T, *ch["key"][0]),
        _mm(f"{prefix}.rwkv.chan[value]", "rwkv.chan[value]",
            T, *ch["value"][0]),
        _mm(f"{prefix}.rwkv.chan[recept]", "rwkv.chan[recept]",
            T, *ch["receptance"][0]),
    ]


def lower_model(cfg: ArchConfig, shape: ShapeSpec | str = "train_4k",
                batch: int = 1) -> ModelPlan:
    """Decompose one forward pass of ``cfg`` at ``shape`` into a kernel plan.

    ``batch`` is the per-chip batch for train/prefill shapes (sequences);
    decode shapes take their token batch from ``shape.global_batch`` (one
    token per sequence per step).  Raises ``ValueError`` for the cells
    ``configs.base.valid_cells`` excludes (long-context on quadratic archs).
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.kind == "long_decode" and not cfg.is_sub_quadratic:
        raise UnsupportedShape(
            f"{cfg.name} cannot lower {shape.name}: quadratic attention "
            "(see DESIGN.md §4)")

    decode = shape.kind in ("decode", "long_decode")
    S = pad_tile(shape.seq_len)             # padded sequence length
    hd = cfg.resolved_head_dim
    if decode:
        B = shape.global_batch              # tokens per decode step
        T = B
        Sq = 1
        ctx = shape.seq_len                 # KV-cache length
    else:
        B = batch
        T = B * S
        Sq = S
        ctx = S
    swa = cfg.swa_window
    Skv = min(ctx, swa) if swa > 0 else ctx
    Skv = pad_tile(Skv) if not decode else Skv

    wls: list = []

    # ---- frontend + encoder (whisper / internvl) -----------------------
    enc_T = 0
    if cfg.enc_layers and not decode:
        enc_T = B * pad_tile(cfg.frontend_tokens)
        wls.append(_mm("frontend.proj", "frontend.proj",
                       enc_T, cfg.frontend_dim, cfg.d_model))
        for i in range(cfg.enc_layers):
            p = f"E{i:02d}"
            wls += _attn_block(p, cfg, T=enc_T, B=B,
                               Sq=pad_tile(cfg.frontend_tokens),
                               Skv=pad_tile(cfg.frontend_tokens),
                               causal=False, decode=False)
            wls += _mlp_block(p, cfg, enc_T)
    elif cfg.frontend == "vision" and not decode:
        # VLM: patch embeddings are projected and prepended to the sequence
        vis_T = B * pad_tile(cfg.frontend_tokens)
        wls.append(_mm("frontend.proj", "frontend.proj",
                       vis_T, cfg.frontend_dim, cfg.d_model))
        T += vis_T
        Sq = Sq + pad_tile(cfg.frontend_tokens)
        Skv = pad_tile(min(Sq, swa)) if swa > 0 else Sq  # keep the SWA clamp

    # ---- decoder stack -------------------------------------------------
    cross_S = pad_tile(cfg.frontend_tokens) if cfg.enc_layers else 0
    pr = lshapes.attention_proj_shapes(cfg.d_model, cfg.n_heads, cfg.n_kv, hd)

    def cross_attn(prefix) -> list:
        # per-layer cross-attention: q from decoder tokens, kv recomputed
        # from the encoder output (mirrors models.lm: no cross-KV cache)
        out = [
            _mm(f"{prefix}.cross.q", "cross.q", T, *pr["q"]),
            _mm(f"{prefix}.cross.kv", "cross.kv", B * cross_S, *pr["kv"]),
        ]
        if decode:
            out += [
                _mm(f"{prefix}.cross.core[qk]", "cross.core[qk]",
                    B, hd, cross_S, count=cfg.n_heads),
                _mm(f"{prefix}.cross.core[av]", "cross.core[av]",
                    B, cross_S, hd, count=cfg.n_heads),
            ]
        else:
            core = _attn_core(f"{prefix}.cross", B=B, Hq=cfg.n_heads,
                              Hkv=cfg.n_kv, Sq=Sq, Skv=cross_S, D=hd,
                              causal=False, decode=False)
            for w in core:
                w.role = w.role.replace("attn.", "cross.", 1)
            out += core
        out.append(_mm(f"{prefix}.cross.out", "cross.out", T, *pr["out"]))
        return out

    if cfg.block_pattern == "attn":
        for i in range(cfg.n_layers):
            p = f"L{i:02d}"
            wls += _attn_block(p, cfg, T=T, B=B, Sq=Sq, Skv=Skv,
                               causal=True, decode=decode)
            if cfg.enc_layers:
                wls += cross_attn(p)
            if cfg.n_experts:
                wls += _moe_block(p, cfg, T)
            else:
                wls += _mlp_block(p, cfg, T)
    elif cfg.block_pattern == "rwkv":
        for i in range(cfg.n_layers):
            wls += _rwkv_block(f"L{i:02d}", cfg, T, decode)
    elif cfg.block_pattern == "mamba_hybrid":
        # k mamba layers per group, then ONE weight-shared attn+MLP block
        # (shared weights, but the compute runs once per group)
        k = cfg.hybrid_attn_every
        for i in range(cfg.n_layers):
            wls += _mamba_block(f"L{i:02d}", cfg, T, decode)
            if (i + 1) % k == 0:
                g = f"G{i // k:02d}"
                wls += _attn_block(g, cfg, T=T, B=B, Sq=Sq, Skv=Skv,
                                   causal=True, decode=decode)
                wls += _mlp_block(g, cfg, T)
    else:
        raise ValueError(cfg.block_pattern)

    # ---- LM head (prefill emits last-token logits only) ----------------
    head_T = B if shape.kind == "prefill" else T
    wls.append(_mm("head.lm", "head.lm",
                   head_T, cfg.d_model, cfg.padded_vocab))

    return ModelPlan(config=cfg, shape=shape, batch=batch, workloads=wls)


def lower_all(shape: ShapeSpec | str = "train_4k", batch: int = 1,
              archs=None) -> dict:
    """Lower every (known or given) arch that supports ``shape``;
    returns ``{arch_name: ModelPlan}`` in config-registry order."""
    from repro.configs import ARCHS, get_config

    plans = {}
    for arch in (archs or ARCHS):
        cfg = get_config(arch)
        try:
            with obs.span("suite.lower", "suite", model=arch):
                plans[arch] = lower_model(cfg, shape, batch)
        except UnsupportedShape:
            continue  # excluded cell (long-context on a quadratic arch)
    return plans
