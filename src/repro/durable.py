"""Durability primitives: atomic writes and an append-only framed journal.

Everything in the pricing stack that survives a process death goes through
this module (DESIGN.md §15).  Two disciplines, two commit points:

*Atomic replace* (:func:`atomic_write`) — for files whose value is their
*latest complete state*: the invariant-cache base blob, bench JSON,
exported traces, memo snapshots.  The data is written to a temp file in the
target directory, fsync'd, ``os.replace``'d over the destination, and the
parent directory is fsync'd so the rename itself is durable.  A crash at
any point leaves either the old complete file or the new complete file.

*Append-only journal* (:class:`Journal`) — for state that accretes: sweep
checkpoints and invariant-cache segments.  Each record is one self-checking
frame::

    MAGIC(4) | length u32 LE | sha256(payload)(32) | payload

The commit point is the ``flush`` + ``fsync`` at the end of
:meth:`Journal.append`: a frame is durable iff the call returned.  On
replay (:func:`scan` / :meth:`Journal.recover`) the file is read frame by
frame; the first frame that fails the magic, length, or digest check ends
the committed prefix.  Recovery truncates the file back to that prefix and
quarantines the torn tail to ``<path>.tail`` for diagnosis — a kill or a
torn write can lose at most the record that was mid-commit, never a
committed one, and never yields a wrong record (the digest rejects partial
or bit-rotted payloads).

Fault sites (DESIGN.md §13): ``io.torn_write`` makes :meth:`Journal.append`
write only a prefix of the frame and then *report success* — the
lying-filesystem model — and ``proc.kill`` (a SIGKILL
:func:`repro.faults.kill_point`) fires after each commit, so plans can die
at exact journal indices.

This module depends only on the stdlib and :mod:`repro.faults` so every
layer (obs, benchmarks, engine, serve) can use it without import cycles;
telemetry spans around recovery/compaction live at the call sites.
"""
from __future__ import annotations

import hashlib
import os
import struct
import tempfile

from repro import faults

FRAME_MAGIC = b"RJ1\x00"
_HEADER = struct.Struct("<4sI32s")     # magic, payload length, sha256
FRAME_OVERHEAD = _HEADER.size

#: hard ceiling on a single frame payload — a corrupted length prefix must
#: not make replay attempt a multi-gigabyte read
MAX_FRAME_BYTES = 1 << 30


def fsync_dir(path: str) -> None:
    """Fsync a directory so a rename/creat inside it is durable.  Best
    effort: some filesystems refuse O_RDONLY dir fsync — a failure degrades
    to "as durable as before", never to an exception."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write(path: str | os.PathLike, data: bytes | str, *,
                 sync: bool = True) -> str:
    """Atomically replace ``path`` with ``data``; return the path written.

    Temp file in the same directory -> write -> fsync(file) ->
    ``os.replace`` -> fsync(parent dir).  Readers never observe a partial
    file, and once this returns the new content survives power loss.
    ``sync=False`` skips both fsyncs for callers that only need atomicity.
    """
    path = os.fspath(path)
    if isinstance(data, str):
        data = data.encode("utf-8")
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".durable-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            if sync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
    except Exception:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    if sync:
        fsync_dir(d)
    return path


def frame(payload: bytes) -> bytes:
    """One self-checking journal frame for ``payload``."""
    return _HEADER.pack(FRAME_MAGIC, len(payload),
                        hashlib.sha256(payload).digest()) + payload


def frames(payloads) -> bytes:
    """A whole journal body (e.g. for a compacted rewrite)."""
    return b"".join(frame(p) for p in payloads)


def scan(path: str | os.PathLike) -> tuple[list[bytes], int, bool]:
    """Replay a journal file without modifying it.

    Returns ``(payloads, valid_bytes, torn)``: every frame of the committed
    prefix, the byte offset where that prefix ends, and whether trailing
    bytes beyond it exist (a torn or corrupt tail).  A missing file is an
    empty, un-torn journal.
    """
    path = os.fspath(path)
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError:
        return [], 0, False
    payloads: list[bytes] = []
    off = 0
    while off + FRAME_OVERHEAD <= len(raw):
        magic, length, digest = _HEADER.unpack_from(raw, off)
        if magic != FRAME_MAGIC or length > MAX_FRAME_BYTES:
            break
        start = off + FRAME_OVERHEAD
        end = start + length
        if end > len(raw):
            break                       # torn mid-payload
        payload = raw[start:end]
        if hashlib.sha256(payload).digest() != digest:
            break
        payloads.append(payload)
        off = end
    return payloads, off, off < len(raw)


class Journal:
    """Append-only record log over one file; safe to reopen after a kill.

    ``append`` is the commit: open in append mode, write one frame, flush,
    fsync.  ``recover`` replays the committed prefix, truncates any torn
    tail (quarantining it to ``<path>.tail``), and leaves the file ready
    for further appends.  Instances are cheap — no file handle is held
    between appends, so a SIGKILL between calls never corrupts state.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        self.appended = 0

    def append(self, payload: bytes) -> int:
        """Durably append one record; return its frame index this process.

        Carries two fault sites: ``io.torn_write`` writes only a prefix of
        the frame and still returns (the lying filesystem), and
        ``proc.kill`` SIGKILLs the process *after* the commit — so a plan
        ``at=(k,)`` dies with exactly ``k + 1`` frames durable.
        """
        data = frame(payload)
        if faults.fire("io.torn_write") is not None:
            data = data[:max(1, len(data) // 2)]
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        with open(self.path, "ab") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        index = self.appended
        self.appended += 1
        faults.kill_point("proc.kill")
        return index

    def recover(self, *, quarantine: bool = True) -> tuple[list[bytes], bool]:
        """Replay the committed prefix and truncate any torn tail.

        Returns ``(payloads, torn)``.  When ``quarantine`` is set the torn
        tail bytes are preserved at ``<path>.tail`` before truncation so
        the evidence survives for diagnosis.
        """
        payloads, valid, torn = scan(self.path)
        if torn:
            try:
                with open(self.path, "rb") as f:
                    f.seek(valid)
                    tail = f.read()
                if quarantine and tail:
                    atomic_write(self.path + ".tail", tail)
                with open(self.path, "rb+") as f:
                    f.truncate(valid)
                    f.flush()
                    os.fsync(f.fileno())
            except OSError:
                pass
        return payloads, torn

    def rewrite(self, payloads) -> int:
        """Atomically replace the whole journal (compaction); returns the
        number of frames written.  Any stale ``.tail`` quarantine is left
        in place — it describes a previous incident, not this file."""
        payloads = list(payloads)
        atomic_write(self.path, frames(payloads))
        return len(payloads)

    def size(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0

    def remove(self) -> None:
        """Delete the journal file (after its contents were folded into a
        compacted base); durable against the directory."""
        try:
            os.unlink(self.path)
        except OSError:
            return
        fsync_dir(os.path.dirname(os.path.abspath(self.path)))


__all__ = [
    "FRAME_MAGIC", "FRAME_OVERHEAD", "MAX_FRAME_BYTES",
    "atomic_write", "fsync_dir", "frame", "frames", "scan", "Journal",
]
